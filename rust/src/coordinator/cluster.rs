//! The `Cluster` façade: one object a user program (or the CLI) drives.
//!
//! Composition per the paper:
//!   * the SLURM controller with the §3.4 power policy (ground-truth
//!     power/energy integration lives there);
//!   * one §4 main board per compute node, whose probe samples that
//!     ground-truth signal at 1000 SPS / mW resolution — co-simulated
//!     between scheduler events (power is piecewise constant there);
//!   * the LDAP user directory and the §4.3 energy API;
//!   * optionally a PJRT runtime: payload-backed jobs execute the real
//!     AOT artifact once on the request path (correctness + FLOPs
//!     grounding), then the simulated duration scales those FLOPs to
//!     the target node's roofline.

use std::collections::BTreeMap;

use crate::config::ClusterConfig;
use crate::energy::{EnergyApi, MainBoard, ProbeConfig};
use crate::power::Activity;
use crate::runtime::PjRtRuntime;
use crate::services::auth::UserDb;
use crate::sim::SimTime;
use crate::slurm::{JobId, JobSpec, Slurm};
use crate::util::Xoshiro256;

/// Cluster-level summary for reports.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub now: SimTime,
    pub jobs_completed: u64,
    pub jobs_pending: usize,
    pub cluster_watts: f64,
    pub true_energy_j: f64,
    /// energy integrated from probe samples (should track true_energy)
    pub measured_energy_j: f64,
    pub samples: u64,
}

/// Assumed sustained fraction of a node's roofline for payload jobs.
/// GEMM-class kernels on consumer CPUs sustain roughly a quarter of
/// peak FMA throughput; documented in DESIGN.md §Perf.
const CPU_EFFICIENCY: f64 = 0.25;
const GPU_EFFICIENCY: f64 = 0.30;

pub struct Cluster {
    pub cfg: ClusterConfig,
    pub slurm: Slurm,
    pub energy: EnergyApi,
    pub users: UserDb,
    pub runtime: Option<PjRtRuntime>,
    rng: Xoshiro256,
    /// nodes with probes attached (board key = node name)
    node_names: Vec<String>,
    sampled_to: SimTime,
}

impl Cluster {
    /// Build the full cluster; `artifact_dir = None` runs without the
    /// PJRT runtime (synthetic workloads only).
    pub fn new(cfg: ClusterConfig, artifact_dir: Option<&str>) -> anyhow::Result<Self> {
        let slurm = Slurm::from_config(&cfg);
        let mut rng = Xoshiro256::new(cfg.seed);
        let mut energy = EnergyApi::new();
        let mut node_names = Vec::new();
        let probe_cfg = ProbeConfig {
            adc_sps: cfg.energy.sample_rate_sps * 4,
            ..ProbeConfig::default()
        };
        for pc in &cfg.partitions {
            for n in 0..pc.nodes {
                let name = format!("{}-{}", pc.name, n);
                let mut board = MainBoard::new(name.clone());
                for probe in 0..cfg.energy.probes_per_node {
                    board
                        .attach_probe(
                            probe as u8,
                            probe_cfg.clone(),
                            rng.fork(&format!("{name}/p{probe}")),
                            4096,
                        )
                        .expect("config bounds probes to 12");
                }
                energy.add_board(board);
                node_names.push(name);
            }
        }
        let mut users = UserDb::new();
        users.add_user("root", true).expect("fresh db");
        let runtime = match artifact_dir {
            Some(dir) => Some(PjRtRuntime::load(dir)?),
            None => None,
        };
        Ok(Self {
            cfg,
            slurm,
            energy,
            users,
            runtime,
            rng,
            node_names,
            sampled_to: SimTime::ZERO,
        })
    }

    pub fn add_user(&mut self, login: &str) {
        let _ = self.users.add_user(login, false);
    }

    /// Submit a synthetic job.
    pub fn submit(&mut self, spec: JobSpec, now: SimTime) -> anyhow::Result<JobId> {
        Ok(self.slurm.submit_at(spec, now)?)
    }

    /// Submit a payload-backed job: executes the AOT artifact once for
    /// real (grounding + checksum), then simulates `iters` iterations
    /// on the target partition's hardware.
    pub fn submit_payload(
        &mut self,
        user: &str,
        partition: &str,
        nodes: u32,
        payload: &str,
        iters: u64,
        now: SimTime,
    ) -> anyhow::Result<JobId> {
        let rt = self
            .runtime
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("no PJRT runtime loaded"))?;
        let report = rt.execute(payload, self.cfg.seed ^ iters)?;
        anyhow::ensure!(
            report.output_sum.is_finite(),
            "payload `{payload}` produced non-finite output"
        );
        let spec_part = crate::config::cluster::resolve_partition(partition)
            .ok_or_else(|| anyhow::anyhow!("unknown partition `{partition}`"))?;
        // GPU-heavy payloads run on the dGPU where one exists
        let on_gpu = spec_part.node.dgpu.is_some()
            && (payload.starts_with("gemm") || payload.starts_with("cnn"));
        let (roofline, eff, activity) = if on_gpu {
            (
                spec_part.node.dgpu.as_ref().expect("checked").peak_f32(),
                GPU_EFFICIENCY,
                Activity {
                    cpu: 0.3,
                    dgpu: 0.95,
                    igpu: 0.0,
                },
            )
        } else {
            (
                spec_part
                    .node
                    .cpu
                    .peak_ops_accumulated(crate::hw::cpu::Instr::FmaF32),
                CPU_EFFICIENCY,
                Activity::cpu_only(0.95),
            )
        };
        let total_flops = report.flops as f64 * iters as f64;
        let per_node = total_flops / nodes as f64;
        let secs = per_node / (roofline * eff);
        let duration = SimTime::from_secs_f64(secs.max(1e-3));
        let spec = JobSpec {
            user: user.into(),
            partition: partition.into(),
            nodes,
            duration,
            time_limit: duration + SimTime::from_mins(10),
            payload: Some(payload.into()),
            activity,
        };
        Ok(self.slurm.submit_at(spec, now)?)
    }

    /// Advance the whole cluster to `t`. When `sample` is set, the §4
    /// boards sample every node's (piecewise-constant) power signal at
    /// the configured rate, replayed exactly from the scheduler's power
    /// history — sampling therefore never misses energy, regardless of
    /// how the scheduler clock advanced (submissions, run_until calls).
    pub fn run_until(&mut self, t: SimTime, sample: bool) {
        self.slurm.run_until(t);
        if !sample {
            return;
        }
        let from = self.sampled_to;
        for name in &self.node_names {
            let hist = self.slurm.node_history(name).expect("known node");
            let board = match self.energy.board_mut(name) {
                Ok(b) => b,
                Err(_) => continue,
            };
            let nprobes = self.cfg.energy.probes_per_node as u8;
            // walk the change points covering (from, t]
            for (i, &(start, w)) in hist.iter().enumerate() {
                let seg_end = hist.get(i + 1).map(|(s, _)| *s).unwrap_or(t).min(t);
                if seg_end <= from || start >= t {
                    continue;
                }
                let sigs: BTreeMap<u8, _> =
                    (0..nprobes).map(|p| (p, move |_t: SimTime| w)).collect();
                board.poll(seg_end, &sigs);
            }
        }
        // §4.3 admin power actions queued via the energy API
        for action in self.energy.drain_actions() {
            let _ = action; // manual power control is reported, not forced
        }
        self.sampled_to = t;
        self.slurm.gc_history(t);
    }

    /// Current summary.
    pub fn report(&self) -> ClusterReport {
        let samples = self
            .energy
            .boards()
            .map(|b| {
                (0..self.cfg.energy.probes_per_node as u8)
                    .filter_map(|p| b.store(p).ok())
                    .map(|s| s.total_samples())
                    .sum::<u64>()
            })
            .sum();
        ClusterReport {
            now: self.slurm.now(),
            jobs_completed: self.slurm.stats.completed,
            jobs_pending: self.slurm.pending_count(),
            cluster_watts: self.slurm.cluster_watts(),
            true_energy_j: self.slurm.total_energy_j(),
            measured_energy_j: self.energy.total_energy_j(),
            samples,
        }
    }

    /// Deterministic sub-RNG for workload generators.
    pub fn fork_rng(&mut self, label: &str) -> Xoshiro256 {
        self.rng.fork(label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slurm::JobState;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig::dalek_default(), None).unwrap()
    }

    fn artifacts_dir() -> Option<&'static str> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        std::path::Path::new(dir)
            .join("manifest.json")
            .exists()
            .then_some(dir)
    }

    #[test]
    fn builds_16_boards() {
        let c = cluster();
        assert_eq!(c.energy.boards().count(), 16);
        assert_eq!(c.node_names.len(), 16);
    }

    #[test]
    fn measured_energy_tracks_truth() {
        let mut c = cluster();
        c.submit(JobSpec::cpu("root", "az5-a890m", 2, 120), SimTime::ZERO)
            .unwrap();
        c.run_until(SimTime::from_mins(8), true);
        let r = c.report();
        assert!(r.samples > 0);
        assert!(r.true_energy_j > 0.0);
        // probes quantize to mW and add noise; agreement within 1%
        let rel = (r.measured_energy_j - r.true_energy_j).abs() / r.true_energy_j;
        assert!(rel < 0.01, "rel error {rel}: {r:?}");
    }

    #[test]
    fn sampling_rate_is_configured_1000_sps() {
        let mut c = cluster();
        c.run_until(SimTime::from_secs(10), true);
        let r = c.report();
        // 16 nodes x 1 probe x 1000 SPS x 10 s
        let expect = 16.0 * 1000.0 * 10.0;
        let got = r.samples as f64;
        assert!((got - expect).abs() / expect < 0.01, "{got} vs {expect}");
    }

    #[test]
    fn unsampled_run_is_cheap_and_equivalent_in_truth() {
        let mut a = cluster();
        let mut b = cluster();
        a.submit(JobSpec::cpu("root", "az4-n4090", 4, 300), SimTime::ZERO)
            .unwrap();
        b.submit(JobSpec::cpu("root", "az4-n4090", 4, 300), SimTime::ZERO)
            .unwrap();
        a.run_until(SimTime::from_mins(30), false);
        b.run_until(SimTime::from_mins(30), true);
        let (ra, rb) = (a.report(), b.report());
        assert_eq!(ra.jobs_completed, rb.jobs_completed);
        assert!((ra.true_energy_j - rb.true_energy_j).abs() < 1e-6);
        assert_eq!(ra.samples, 0);
    }

    #[test]
    fn payload_job_runs_real_artifact_then_simulates() {
        let Some(dir) = artifacts_dir() else { return };
        let mut c = Cluster::new(ClusterConfig::dalek_default(), Some(dir)).unwrap();
        c.add_user("alice");
        let id = c
            .submit_payload("alice", "az4-n4090", 2, "gemm256", 50_000, SimTime::ZERO)
            .unwrap();
        c.run_until(SimTime::from_hours(2), false);
        let job = c.slurm.job(id).unwrap();
        assert_eq!(job.state, JobState::Completed, "{:?}", job.state);
        assert_eq!(job.spec.payload.as_deref(), Some("gemm256"));
        // GPU-backed duration: 50k x 33.5 MFLOP / 2 nodes on 4090s
        // (≈0.84 TFLOP/node over a ~25 TFLOP/s effective roofline)
        let d = job.spec.duration.as_secs_f64();
        assert!(d > 0.01 && d < 600.0, "duration {d}");
        // sanity: the same payload on the CPU-only partition is slower
        let id2 = c
            .submit_payload("alice", "az5-a890m", 2, "gemm256", 50_000, c.slurm.now())
            .unwrap();
        c.run_until(c.slurm.now() + SimTime::from_hours(4), false);
        let d2 = c.slurm.job(id2).unwrap().spec.duration.as_secs_f64();
        assert!(d2 > 5.0 * d, "CPU {d2} vs GPU {d}");
    }

    #[test]
    fn payload_requires_runtime() {
        let mut c = cluster();
        assert!(c
            .submit_payload("root", "az4-n4090", 1, "gemm256", 1, SimTime::ZERO)
            .is_err());
    }
}
