//! The frontend daemon: everything `front.dalek` does, wired together.
//!
//! * [`cluster`] — the `Cluster` façade: SLURM controller + energy
//!   measurement platform + user directory + (optionally) the PJRT
//!   runtime executing real AOT payloads on the request path
//! * [`trace`] — workload trace generation and replay, producing the
//!   end-to-end reports (throughput, wait, energy) of the examples and
//!   the e2e bench

pub mod cluster;
pub mod trace;

pub use cluster::{Cluster, ClusterReport};
pub use trace::{ReplayReport, TraceEvent, TraceGen};
