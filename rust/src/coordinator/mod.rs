//! The frontend daemon: everything `front.dalek` does, wired together.
//!
//! The cluster façade itself lives in [`crate::api`]: [`Cluster`] is
//! the session-based [`crate::api::ClusterApi`] — one object that
//! composes the SLURM controller, the §4 energy measurement platform,
//! the user directory and (optionally) the PJRT runtime, and fronts
//! them with the unified request/response protocol.
//!
//! * [`trace`] — workload trace generation and replay, producing the
//!   end-to-end reports (throughput, wait, energy) of the examples and
//!   the e2e bench; replay drives the same [`Cluster`] surface users do

pub mod trace;

pub use crate::api::{ClusterApi as Cluster, ClusterReport};
pub use trace::{ReplayReport, TraceEvent, TraceGen};
