//! Workload traces: generation and replay.
//!
//! The end-to-end driver replays a mixed trace (CPU jobs, GPU payload
//! jobs, several partitions, Poisson arrivals) through the full stack
//! and reports the numbers the examples and the e2e bench print:
//! throughput, waiting times, node utilization, true vs measured energy.

use crate::api::protocol::{JobRequest, Request};
use crate::api::Channel;
use crate::api::ClusterApi as Cluster;
use crate::app::{AppSpec, Collective, PhaseSpec};
use crate::power::Activity;
use crate::sim::SimTime;
use crate::slurm::{JobId, JobSpec, JobState};
use crate::util::stats::Summary;
use crate::util::Xoshiro256;

/// One trace entry.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub at: SimTime,
    pub spec: JobSpec,
    /// payload-backed jobs carry (payload, iters) for the runtime path
    pub payload: Option<(String, u64)>,
}

/// Trace generator: Poisson arrivals over a partition/shape mix.
pub struct TraceGen {
    pub rng: Xoshiro256,
    /// mean arrival rate, jobs per hour
    pub jobs_per_hour: f64,
    /// (partition, max nodes) choices
    pub partitions: Vec<(String, u32)>,
    /// payload mix for runtime-backed jobs (empty = synthetic only)
    pub payloads: Vec<String>,
    /// fraction of jobs that are payload-backed (when payloads exist)
    pub payload_fraction: f64,
    /// partitions whose jobs also load the discrete GPU (the §3.6
    /// power-cap studies need GPU-heavy draw on the dGPU partitions)
    pub gpu_partitions: Vec<String>,
    /// fraction of (non-payload) jobs that are phase-structured
    /// `dalek::app` programs — cnn-train-like allreduce loops, stencil
    /// halo patterns, and NFS-heavy prototyping mixes. 0.0 keeps the
    /// classic mixes bit-identical (no RNG draw is consumed)
    pub app_fraction: f64,
    /// multi-tenant demand skew: each job's owner is drawn from this
    /// `(user, weight)` table. Empty keeps the legacy round-robin
    /// `user{i % 7}` naming bit-identical (no RNG draw is consumed) —
    /// the fair-share studies use [`TraceGen::tenant_mix`]
    pub user_weights: Vec<(String, f64)>,
}

impl TraceGen {
    pub fn dalek_mix(seed: u64) -> Self {
        Self {
            rng: Xoshiro256::new(seed),
            jobs_per_hour: 40.0,
            partitions: vec![
                ("az4-n4090".into(), 4),
                ("az4-a7900".into(), 4),
                ("iml-ia770".into(), 4),
                ("az5-a890m".into(), 4),
            ],
            payloads: vec!["gemm256".into(), "cnn_small".into(), "mlp_infer".into()],
            payload_fraction: 0.3,
            gpu_partitions: Vec::new(),
            app_fraction: 0.0,
            user_weights: Vec::new(),
        }
    }

    /// The §3.6 power-cap study mix: dense synthetic arrivals that keep
    /// every partition busy, with GPU-heavy activity on the dGPU
    /// partitions — the workload `benches/powercap.rs` and the scenario
    /// suite squeeze under shrinking budgets.
    pub fn powercap_mix(seed: u64) -> Self {
        Self {
            rng: Xoshiro256::new(seed),
            jobs_per_hour: 240.0,
            partitions: vec![
                ("az4-n4090".into(), 4),
                ("az4-a7900".into(), 4),
                ("iml-ia770".into(), 4),
                ("az5-a890m".into(), 4),
            ],
            payloads: Vec::new(),
            payload_fraction: 0.0,
            gpu_partitions: vec!["az4-n4090".into(), "az4-a7900".into()],
            app_fraction: 0.0,
            user_weights: Vec::new(),
        }
    }

    /// The application-shaped mix: a majority of jobs carry
    /// phase-structured programs (cnn-train-like allreduce loops,
    /// stencil halo exchanges, NFS-heavy prototyping pulls) riding the
    /// flow network, interleaved with classic opaque jobs — the
    /// workload `benches/appmodel.rs` sweeps.
    pub fn app_mix(seed: u64) -> Self {
        Self {
            rng: Xoshiro256::new(seed),
            jobs_per_hour: 30.0,
            partitions: vec![
                ("az4-n4090".into(), 4),
                ("az4-a7900".into(), 4),
                ("iml-ia770".into(), 4),
                ("az5-a890m".into(), 4),
            ],
            payloads: Vec::new(),
            payload_fraction: 0.0,
            gpu_partitions: Vec::new(),
            app_fraction: 0.6,
            user_weights: Vec::new(),
        }
    }

    /// The chaos-suite mix: steady arrivals dense enough that a seeded
    /// [`FaultPlan`](crate::faults::FaultPlan) reliably lands faults on
    /// busy nodes, with a slice of phase-structured `dalek::app`
    /// programs so crash recovery exercises both the classic work
    /// ledger and BSP barrier checkpointing, and GPU draw on the dGPU
    /// partitions so brownout floors actually bind. Pairs with
    /// `ClusterApi::install_fault_plan` in the golden chaos scenarios
    /// (`tests/chaos.rs`).
    pub fn chaos_mix(seed: u64) -> Self {
        Self {
            rng: Xoshiro256::new(seed),
            jobs_per_hour: 120.0,
            partitions: vec![
                ("az4-n4090".into(), 4),
                ("az4-a7900".into(), 4),
                ("iml-ia770".into(), 4),
                ("az5-a890m".into(), 4),
            ],
            payloads: Vec::new(),
            payload_fraction: 0.0,
            gpu_partitions: vec!["az4-n4090".into(), "az4-a7900".into()],
            app_fraction: 0.25,
            user_weights: Vec::new(),
        }
    }

    /// The multi-tenant fair-share mix: dense synthetic arrivals whose
    /// owners are drawn from a Zipf-like skew over `users` tenants
    /// (`user0` weighted 1, `user1` ½, `user2` ⅓, …) — a single greedy
    /// tenant dominating the queue, which is exactly what the
    /// fair-share sort and preemption exist to correct. Classic jobs
    /// only: the fairness and endurance suites measure allocation and
    /// conservation against the work ledger.
    pub fn tenant_mix(seed: u64, users: usize) -> Self {
        assert!(users >= 2, "a tenant mix needs at least two tenants");
        Self {
            rng: Xoshiro256::new(seed),
            jobs_per_hour: 180.0,
            partitions: vec![
                ("az4-n4090".into(), 4),
                ("az4-a7900".into(), 4),
                ("iml-ia770".into(), 4),
                ("az5-a890m".into(), 4),
            ],
            payloads: Vec::new(),
            payload_fraction: 0.0,
            gpu_partitions: Vec::new(),
            app_fraction: 0.0,
            user_weights: (0..users)
                .map(|k| (format!("user{k}"), 1.0 / (k + 1) as f64))
                .collect(),
        }
    }

    /// Draw one owner from the weight table (weights need not sum to 1).
    fn weighted_user(&mut self) -> String {
        let total: f64 = self.user_weights.iter().map(|(_, w)| w).sum();
        let mut x = self.rng.next_f64() * total;
        for (u, w) in &self.user_weights {
            x -= w;
            if x <= 0.0 {
                return u.clone();
            }
        }
        self.user_weights.last().expect("non-empty table").0.clone()
    }

    /// Generate `n` jobs starting at t=0.
    pub fn generate(&mut self, n: usize) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(n);
        let mut t = 0.0f64;
        for i in 0..n {
            t += self.rng.exponential(self.jobs_per_hour / 3600.0);
            let (part, max_nodes) = self.rng.choose(&self.partitions).clone();
            let nodes = 1 + self.rng.uniform_u64(0, max_nodes as u64 - 1) as u32;
            let dur_s = 30.0 + self.rng.exponential(1.0 / 240.0); // mean ~4.5 min
            let use_payload =
                !self.payloads.is_empty() && self.rng.next_f64() < self.payload_fraction;
            let payload = use_payload.then(|| {
                let p = self.rng.choose(&self.payloads).clone();
                let iters = 10_000 + self.rng.uniform_u64(0, 90_000);
                (p, iters)
            });
            let mut activity = Activity::cpu_only(self.rng.uniform_f64(0.6, 1.0));
            if self.gpu_partitions.contains(&part) {
                activity.dgpu = self.rng.uniform_f64(0.7, 1.0);
            }
            // phase-structured programs: drawn only when enabled, so a
            // zero app_fraction consumes no RNG and the classic mixes
            // stay bit-identical (payload jobs stay classic)
            let use_app = self.app_fraction > 0.0
                && !use_payload
                && self.rng.next_f64() < self.app_fraction;
            let app = use_app.then(|| {
                let kind = self.rng.uniform_u64(0, 2);
                let work_s = 10.0 + self.rng.uniform_f64(0.0, 50.0);
                let bytes = (8 + self.rng.uniform_u64(0, 56)) * 1_000_000;
                let iters = 3 + self.rng.uniform_u64(0, 7) as u32;
                match kind {
                    0 => AppSpec::allreduce_loop("cnn-train", work_s, bytes, iters),
                    1 => AppSpec::halo_loop("stencil", work_s, bytes, iters),
                    // prototyping: pull an NFS shard, compute, publish
                    // a (smaller) result from rank 0
                    _ => AppSpec::new(
                        "proto-nfs",
                        vec![
                            PhaseSpec::Collective(Collective::NfsPull { bytes }),
                            PhaseSpec::Compute { work_s },
                            PhaseSpec::Collective(Collective::Bcast {
                                root: 0,
                                bytes: bytes / 8,
                            }),
                        ],
                        iters,
                    ),
                }
            });
            // app jobs: duration is the program's work ledger and the
            // limit leaves room for communication wall time
            let (duration, time_limit) = match &app {
                Some(a) => {
                    let w = a.compute_work_s();
                    (
                        SimTime::from_secs_f64(w),
                        SimTime::from_secs_f64(w * 4.0 + 3600.0),
                    )
                }
                None => (
                    SimTime::from_secs_f64(dur_s),
                    SimTime::from_secs_f64(dur_s * 4.0 + 120.0),
                ),
            };
            // skewed tenants draw from the weight table; the empty
            // table keeps the legacy round-robin naming without
            // consuming an RNG draw (classic mixes stay bit-identical)
            let user = if self.user_weights.is_empty() {
                format!("user{}", i % 7)
            } else {
                self.weighted_user()
            };
            let spec = JobSpec {
                user,
                partition: part,
                nodes,
                duration,
                time_limit,
                payload: None,
                activity,
                app,
            };
            out.push(TraceEvent {
                at: SimTime::from_secs_f64(t),
                spec,
                payload,
            });
        }
        out
    }
}

/// One arrival in a multi-client API storm: at `at`, client `client`
/// enqueues `request` on the [`ApiServer`](crate::api::ApiServer).
#[derive(Clone, Debug)]
pub struct StormEvent {
    pub at: SimTime,
    pub client: usize,
    pub request: Request,
}

impl TraceGen {
    /// Generate a seeded multi-client request storm for the
    /// `ApiServer`: `clients` concurrent sessions (client 0 is the
    /// operator, `root`; the rest are `user1..`) firing `n` Poisson
    /// arrivals that mix srun tickets, plain submissions, job lookups,
    /// energy queries, subscriptions (job events, telemetry at varied
    /// rates, the operator's power-events feed), event polls, and
    /// operator-only actions (power budgets, rate-limit overrides).
    /// Entirely RNG-driven off `self.rng`: the same seed replays
    /// bit-for-bit — the reproducible "storm" the determinism suite
    /// and `benches/api_throughput.rs` replay.
    pub fn client_storm(&mut self, clients: usize, n: usize) -> Vec<StormEvent> {
        assert!(clients >= 2, "a storm needs an operator and at least one user");
        let mut out = Vec::with_capacity(n);
        let mut t = 0.0f64;
        for _ in 0..n {
            t += self.rng.exponential(self.jobs_per_hour / 3600.0);
            let client = self.rng.uniform_u64(0, clients as u64 - 1) as usize;
            let (part, max_nodes) = self.rng.choose(&self.partitions).clone();
            let job_req = |rng: &mut Xoshiro256| JobRequest {
                partition: part.clone(),
                nodes: 1 + rng.uniform_u64(0, max_nodes as u64 - 1) as u32,
                duration: SimTime::from_secs_f64(20.0 + rng.uniform_f64(0.0, 280.0)),
                time_limit: None,
                payload: None,
                iters: 1,
                user: None,
                app: None,
            };
            let request = match self.rng.uniform_u64(0, 9) {
                0 | 1 => Request::SubmitJob(job_req(&mut self.rng)),
                // srun ticket: nonblocking, progress via JobEvents
                2 | 3 => Request::RunJob(job_req(&mut self.rng)),
                4 => Request::JobInfo {
                    job: JobId(1 + self.rng.uniform_u64(0, 30)),
                },
                5 => Request::QueryEnergy {
                    node: None,
                    window: None,
                },
                6 => Request::Subscribe {
                    channel: if self.rng.next_f64() < 0.5 {
                        Channel::JobEvents
                    } else {
                        Channel::Telemetry
                    },
                    rate_hz: Some(
                        [0.2, 1.0, 2.0, 10.0][self.rng.uniform_u64(0, 3) as usize],
                    ),
                    expr: None,
                },
                7 => Request::PollEvents {
                    max: 1 + self.rng.uniform_u64(0, 63) as u32,
                },
                8 => Request::ClusterReport,
                // operator actions land on client 0 regardless of who
                // drew them — capability-scoped ops from non-admins
                // would only exercise the error path
                _ => {
                    push_operator_op(&mut self.rng, &mut out, t);
                    continue;
                }
            };
            out.push(StormEvent {
                at: SimTime::from_secs_f64(t),
                client,
                request,
            });
        }
        out
    }

    /// Generate a fleet-scale request storm for a
    /// [`ClusterConfig::fleet(nodes)`](crate::config::ClusterConfig::fleet)
    /// cluster: `sessions` concurrent clients firing `jobs` arrivals —
    /// mostly short plain submissions over the four scaled catalog
    /// partitions, with srun tickets, job lookups, event polls, and
    /// cluster reports mixed in. Arrivals are compressed into a fixed
    /// ~20-sim-minute window regardless of `jobs`, so the drained
    /// makespan (and the per-second prober sweeps riding it) stays
    /// bounded as the storm grows. Entirely RNG-driven off `self.rng`:
    /// the same seed replays bit-for-bit.
    pub fn fleet_storm(&mut self, nodes: u32, jobs: usize, sessions: usize) -> Vec<StormEvent> {
        assert!(sessions >= 2, "a storm needs an operator and at least one user");
        assert!(nodes >= 4, "one node per catalog partition at minimum");
        let parts = ["az4-n4090", "az4-a7900", "iml-ia770", "az5-a890m"];
        let window_s = 1_200.0;
        let rate = jobs as f64 / window_s; // arrivals per sim-second
        let mut out = Vec::with_capacity(jobs);
        let mut t = 0.0f64;
        for _ in 0..jobs {
            t += self.rng.exponential(rate);
            let client = self.rng.uniform_u64(0, sessions as u64 - 1) as usize;
            let part = parts[self.rng.uniform_u64(0, 3) as usize];
            let job_req = |rng: &mut Xoshiro256| JobRequest {
                partition: part.into(),
                nodes: 1 + rng.uniform_u64(0, 3) as u32,
                duration: SimTime::from_secs_f64(60.0 + rng.uniform_f64(0.0, 120.0)),
                time_limit: None,
                payload: None,
                iters: 1,
                user: None,
                app: None,
            };
            let request = match self.rng.uniform_u64(0, 9) {
                0..=5 => Request::SubmitJob(job_req(&mut self.rng)),
                6 => Request::RunJob(job_req(&mut self.rng)),
                7 => Request::JobInfo {
                    job: JobId(1 + self.rng.uniform_u64(0, jobs as u64)),
                },
                8 => Request::PollEvents {
                    max: 1 + self.rng.uniform_u64(0, 63) as u32,
                },
                _ => Request::ClusterReport,
            };
            out.push(StormEvent {
                at: SimTime::from_secs_f64(t),
                client,
                request,
            });
        }
        out
    }
}

/// One operator-plane arrival (client 0): budget moves, power-events
/// subscription, rate-limit overrides, governor report reads.
fn push_operator_op(rng: &mut Xoshiro256, out: &mut Vec<StormEvent>, t: f64) {
    let request = match rng.uniform_u64(0, 3) {
        0 => Request::SetPowerBudget {
            watts: Some(400.0 + rng.uniform_f64(0.0, 800.0)),
        },
        1 => Request::Subscribe {
            channel: Channel::PowerEvents,
            rate_hz: None,
            expr: None,
        },
        2 => Request::SetRateLimit {
            user: format!("user{}", 1 + rng.uniform_u64(0, 5)),
            ops: 1 + rng.uniform_u64(0, 7) as u32,
        },
        _ => Request::PowerReport,
    };
    out.push(StormEvent {
        at: SimTime::from_secs_f64(t),
        client: 0,
        request,
    });
}

/// Replay results.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    pub jobs: usize,
    pub completed: u64,
    pub timeouts: u64,
    pub makespan: SimTime,
    pub wait: Option<Summary>,
    pub true_energy_j: f64,
    pub measured_energy_j: f64,
    pub mean_cluster_w: f64,
    pub throughput_jobs_per_hour: f64,
}

/// Replay a trace through a cluster. `sample` turns on 1 ms energy
/// sampling (slower; the e2e bench measures both modes).
pub fn replay(cluster: &mut Cluster, trace: &[TraceEvent], sample: bool) -> ReplayReport {
    for ev in trace {
        match &ev.payload {
            Some((payload, iters)) if cluster.has_runtime() => {
                cluster
                    .submit_payload(
                        &ev.spec.user.clone(),
                        &ev.spec.partition.clone(),
                        ev.spec.nodes,
                        payload,
                        *iters,
                        ev.at,
                    )
                    .expect("valid trace");
            }
            _ => {
                cluster.submit(ev.spec.clone(), ev.at).expect("valid trace");
            }
        }
        if sample {
            cluster.run_until(ev.at, true);
        }
    }
    // drain to quiescence: run in day-long strides until no pending work
    let mut horizon = cluster.now() + SimTime::from_hours(1);
    loop {
        cluster.run_until(horizon, sample);
        let all_terminal = cluster.slurm().jobs().all(|j| j.is_terminal());
        if all_terminal {
            break;
        }
        horizon += SimTime::from_hours(1);
        assert!(
            horizon < SimTime::from_hours(24 * 30),
            "trace failed to drain"
        );
    }
    let last_finish = cluster
        .slurm()
        .jobs()
        .filter_map(|j| j.finished)
        .max()
        .unwrap_or(SimTime::ZERO);
    let waits: Vec<f64> = cluster
        .slurm()
        .jobs()
        .filter(|j| j.state == JobState::Completed)
        .filter_map(|j| j.wait_time())
        .map(|w| w.as_secs_f64())
        .collect();
    let report = cluster.report();
    let makespan = last_finish;
    ReplayReport {
        jobs: trace.len(),
        completed: report.jobs_completed,
        timeouts: cluster.slurm().stats.timeouts,
        makespan,
        wait: Summary::of(&waits),
        true_energy_j: report.true_energy_j,
        measured_energy_j: report.measured_energy_j,
        mean_cluster_w: report.true_energy_j / report.now.as_secs_f64().max(1e-9),
        throughput_jobs_per_hour: report.jobs_completed as f64
            / (makespan.as_secs_f64() / 3600.0).max(1e-9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    #[test]
    fn generator_is_deterministic_and_ordered() {
        let a = TraceGen::dalek_mix(3).generate(50);
        let b = TraceGen::dalek_mix(3).generate(50);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.spec.partition, y.spec.partition);
        }
        // arrivals strictly increasing
        for w in a.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }

    #[test]
    fn trace_nodes_within_partition_bounds() {
        let t = TraceGen::dalek_mix(5).generate(200);
        for ev in &t {
            assert!((1..=4).contains(&ev.spec.nodes));
        }
    }

    #[test]
    fn powercap_mix_is_dense_gpu_heavy_and_deterministic() {
        let a = TraceGen::powercap_mix(9).generate(60);
        let b = TraceGen::powercap_mix(9).generate(60);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.spec.activity, y.spec.activity);
        }
        // dGPU partitions carry GPU load, the others stay CPU-only
        for ev in &a {
            let gpu_part = ev.spec.partition.starts_with("az4");
            assert_eq!(ev.spec.payload, None);
            if gpu_part {
                assert!(ev.spec.activity.dgpu >= 0.7, "{:?}", ev.spec);
            } else {
                assert_eq!(ev.spec.activity.dgpu, 0.0);
            }
        }
        // dense arrivals: 60 jobs inside ~half an hour on average
        assert!(a.last().unwrap().at < SimTime::from_hours(1));
    }

    #[test]
    fn app_mix_is_deterministic_and_valid() {
        let a = TraceGen::app_mix(17).generate(40);
        let b = TraceGen::app_mix(17).generate(40);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.spec.app, y.spec.app);
        }
        // the mix actually contains programs, of every kind, and they
        // are valid for their rank counts
        let apps: Vec<&TraceEvent> = a.iter().filter(|e| e.spec.app.is_some()).collect();
        assert!(apps.len() > 10, "only {} app jobs", apps.len());
        assert!(apps.len() < 40, "no classic jobs left");
        let mut names = std::collections::BTreeSet::new();
        for ev in &apps {
            let app = ev.spec.app.as_ref().unwrap();
            app.validate(ev.spec.nodes).expect("valid program");
            names.insert(app.name.clone());
            // the work ledger is the program's compute total
            assert_eq!(
                ev.spec.duration,
                SimTime::from_secs_f64(app.compute_work_s())
            );
        }
        assert!(names.len() >= 2, "one-note mix: {names:?}");
    }

    #[test]
    fn app_mix_replay_completes() {
        let mut gen = TraceGen::app_mix(23);
        let trace = gen.generate(12);
        assert!(trace.iter().any(|e| e.spec.app.is_some()));
        let mut cluster = Cluster::new(ClusterConfig::dalek_default(), None).unwrap();
        let report = replay(&mut cluster, &trace, false);
        assert_eq!(report.completed + report.timeouts, 12);
        assert_eq!(report.timeouts, 0, "app limits leave comm headroom");
    }

    #[test]
    fn chaos_mix_is_deterministic_and_mixed() {
        let a = TraceGen::chaos_mix(31).generate(100);
        let b = TraceGen::chaos_mix(31).generate(100);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.spec.app, y.spec.app);
            assert_eq!(x.spec.activity, y.spec.activity);
        }
        // the mix carries both classic jobs and programs, and the dGPU
        // partitions draw GPU power (so brownout floors bind)
        let apps = a.iter().filter(|e| e.spec.app.is_some()).count();
        assert!(apps > 5, "only {apps} app jobs");
        assert!(apps < 100, "no classic jobs left");
        assert!(a
            .iter()
            .any(|e| e.spec.partition.starts_with("az4") && e.spec.activity.dgpu >= 0.7));
        // dense: 100 jobs arrive within ~an hour on average
        assert!(a.last().unwrap().at < SimTime::from_hours(2));
    }

    #[test]
    fn client_storm_is_deterministic_and_well_formed() {
        let a = TraceGen::dalek_mix(21).client_storm(8, 120);
        let b = TraceGen::dalek_mix(21).client_storm(8, 120);
        assert_eq!(a.len(), 120);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.client, y.client);
            assert_eq!(x.request, y.request);
        }
        // arrivals non-decreasing, clients in range, the mix is a mix
        let mut tickets = 0;
        let mut subs = 0;
        let mut admin = 0;
        for w in a.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        for ev in &a {
            assert!(ev.client < 8);
            match &ev.request {
                Request::RunJob(_) => tickets += 1,
                Request::Subscribe { .. } => subs += 1,
                Request::SetPowerBudget { .. } | Request::SetRateLimit { .. } => {
                    assert_eq!(ev.client, 0, "operator ops go to the operator");
                    admin += 1;
                }
                _ => {}
            }
        }
        assert!(tickets > 5, "{tickets} srun tickets");
        assert!(subs > 2, "{subs} subscriptions");
        assert!(admin > 0, "{admin} operator ops");
    }

    #[test]
    fn fleet_storm_is_deterministic_and_well_formed() {
        let a = TraceGen::dalek_mix(29).fleet_storm(10_000, 2_000, 64);
        let b = TraceGen::dalek_mix(29).fleet_storm(10_000, 2_000, 64);
        assert_eq!(a.len(), 2_000);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.client, y.client);
            assert_eq!(x.request, y.request);
        }
        let mut submits = 0;
        let mut tickets = 0;
        let mut reports = 0;
        for w in a.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        for ev in &a {
            assert!(ev.client < 64);
            match &ev.request {
                Request::SubmitJob(r) => {
                    assert!((1..=4).contains(&r.nodes));
                    submits += 1;
                }
                Request::RunJob(_) => tickets += 1,
                Request::ClusterReport => reports += 1,
                _ => {}
            }
        }
        assert!(submits > 1_000, "{submits} submissions");
        assert!(tickets > 50, "{tickets} srun tickets");
        assert!(reports > 50, "{reports} reports");
        // the arrival window is compressed: bounded regardless of size
        assert!(a.last().unwrap().at < SimTime::from_mins(40));
    }

    #[test]
    fn tenant_mix_is_skewed_and_deterministic() {
        let a = TraceGen::tenant_mix(47, 5).generate(400);
        let b = TraceGen::tenant_mix(47, 5).generate(400);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.spec.user, y.spec.user);
            assert_eq!(x.spec.partition, y.spec.partition);
        }
        // every owner comes from the configured tenant set, the skew
        // materializes (the weight-1 tenant clearly out-submits the
        // weight-⅕ one), and no tenant starves out of the trace itself
        let mut count = std::collections::BTreeMap::new();
        for ev in &a {
            assert!(ev.spec.app.is_none());
            *count.entry(ev.spec.user.clone()).or_insert(0usize) += 1;
        }
        assert_eq!(count.len(), 5, "tenants seen: {count:?}");
        assert!(count["user0"] > 2 * count["user4"], "{count:?}");
    }

    #[test]
    fn zero_app_fraction_consumes_no_rng() {
        // replay the classic draw sequence by hand: if a zero
        // app_fraction (or an empty payload mix) consumed an RNG draw,
        // every subsequent field would shift off this transcript
        let mut g = TraceGen::powercap_mix(41); // payloads empty, apps off
        assert_eq!(g.app_fraction, 0.0);
        let t = g.generate(30);
        let probe = TraceGen::powercap_mix(41);
        let mut rng = Xoshiro256::new(41);
        let mut at = 0.0f64;
        for ev in &t {
            at += rng.exponential(240.0 / 3600.0);
            let (part, max_nodes) = rng.choose(&probe.partitions).clone();
            let nodes = 1 + rng.uniform_u64(0, max_nodes as u64 - 1) as u32;
            let dur_s = 30.0 + rng.exponential(1.0 / 240.0);
            let cpu = rng.uniform_f64(0.6, 1.0);
            let dgpu = if probe.gpu_partitions.contains(&part) {
                rng.uniform_f64(0.7, 1.0)
            } else {
                0.0
            };
            assert_eq!(ev.at, SimTime::from_secs_f64(at));
            assert_eq!(ev.spec.partition, part);
            assert_eq!(ev.spec.nodes, nodes);
            assert_eq!(ev.spec.duration, SimTime::from_secs_f64(dur_s));
            assert_eq!(ev.spec.activity.cpu, cpu);
            assert_eq!(ev.spec.activity.dgpu, dgpu);
            assert!(ev.spec.app.is_none());
        }
    }

    #[test]
    fn replay_small_trace_completes() {
        let mut gen = TraceGen::dalek_mix(7);
        gen.payloads.clear(); // synthetic only (no runtime in unit tests)
        let trace = gen.generate(30);
        let mut cluster = Cluster::new(ClusterConfig::dalek_default(), None).unwrap();
        let report = replay(&mut cluster, &trace, false);
        assert_eq!(report.jobs, 30);
        assert_eq!(report.completed + report.timeouts, 30);
        assert!(report.makespan > SimTime::ZERO);
        assert!(report.true_energy_j > 0.0);
        assert!(report.throughput_jobs_per_hour > 0.0);
        let w = report.wait.unwrap();
        // waits include boot delays but nothing pathological
        assert!(w.max < 3600.0, "max wait {w:?}");
    }

    #[test]
    fn replay_deterministic() {
        let run = || {
            let mut gen = TraceGen::dalek_mix(11);
            gen.payloads.clear();
            let trace = gen.generate(20);
            let mut cluster = Cluster::new(ClusterConfig::dalek_default(), None).unwrap();
            replay(&mut cluster, &trace, false)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.true_energy_j, b.true_energy_j);
    }

    #[test]
    fn power_policy_saves_energy_on_sparse_trace() {
        // the §3.4 claim, end to end: with suspend enabled, a sparse
        // trace costs much less energy than with nodes always on
        let mut gen = TraceGen::dalek_mix(13);
        gen.payloads.clear();
        gen.jobs_per_hour = 4.0; // sparse
        let trace = gen.generate(8);

        let mut on = Cluster::new(ClusterConfig::dalek_default(), None).unwrap();
        let r_on = replay(&mut on, &trace, false);

        let mut cfg = ClusterConfig::dalek_default();
        cfg.power.enabled = false;
        let mut off = Cluster::new(cfg, None).unwrap();
        // with the policy off nodes start suspended too, but never
        // resuspend after their first wake — run the same trace
        let r_off = replay(&mut off, &trace, false);

        assert!(
            r_on.true_energy_j < 0.7 * r_off.true_energy_j,
            "suspend policy should save >30%: {} vs {}",
            r_on.true_energy_j,
            r_off.true_energy_j
        );
        // and it must not change what completed
        assert_eq!(r_on.completed, r_off.completed);
    }
}
