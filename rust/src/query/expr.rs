//! DQL expressions: the opath-style AST and its parser.
//!
//! The grammar is deliberately small — dotted paths over the virtual
//! cluster tree, one optional `[field op literal]` predicate per
//! segment, `*` wildcards, and a single aggregation call wrapping a
//! path:
//!
//! ```text
//! query     := aggregate | path
//! aggregate := func '(' path [',' window] ')'
//! func      := 'sum' | 'mean' | 'min' | 'max' | 'count'
//! window    := 'window' '=' dur | 'from' '=' dur ',' 'to' '=' dur
//! path      := segment ('.' segment)*
//! segment   := (ident | '*') [pred]
//! pred      := '[' ident op literal ']'
//! op        := '=' | '!=' | '<' | '<=' | '>' | '>='
//! literal   := '"' chars '"' | number | 'true' | 'false'
//! dur       := number [unit]      unit := ns | us | ms | s | m | h
//! ```
//!
//! Identifiers are runs of `[A-Za-z0-9_-]` (node names like
//! `az5-a890m-0` and numeric job ids are idents). A bare duration
//! number means seconds. Every malformed input is a typed
//! [`DalekError::InvalidQuery`] — the parser never panics.
//!
//! `Display` renders the *canonical* spelling (no extra whitespace,
//! durations in the largest exact unit), and parsing the canonical
//! spelling reproduces the same AST — the round-trip property the
//! query tests pin.

use std::fmt;

use crate::api::error::DalekError;
use crate::sim::SimTime;

/// Aggregation functions over a resolved path set.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AggFunc {
    Sum,
    Mean,
    Min,
    Max,
    /// counts resolved paths; takes no window
    Count,
}

impl AggFunc {
    pub fn as_str(self) -> &'static str {
        match self {
            AggFunc::Sum => "sum",
            AggFunc::Mean => "mean",
            AggFunc::Min => "min",
            AggFunc::Max => "max",
            AggFunc::Count => "count",
        }
    }

    fn from_str(s: &str) -> Option<Self> {
        Some(match s {
            "sum" => AggFunc::Sum,
            "mean" => AggFunc::Mean,
            "min" => AggFunc::Min,
            "max" => AggFunc::Max,
            "count" => AggFunc::Count,
            _ => return None,
        })
    }
}

/// Predicate comparison operators.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum CmpOp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

impl CmpOp {
    pub fn as_str(self) -> &'static str {
        match self {
            CmpOp::Eq => "=",
            CmpOp::Ne => "!=",
            CmpOp::Lt => "<",
            CmpOp::Le => "<=",
            CmpOp::Gt => ">",
            CmpOp::Ge => ">=",
        }
    }
}

/// Predicate literal values.
#[derive(Clone, PartialEq, Debug)]
pub enum Literal {
    Num(f64),
    Str(String),
    Bool(bool),
}

/// One `[field op literal]` filter on a segment's children.
#[derive(Clone, PartialEq, Debug)]
pub struct Pred {
    pub field: String,
    pub op: CmpOp,
    pub value: Literal,
}

/// A segment's key: a literal name or the `*` wildcard.
#[derive(Clone, PartialEq, Debug)]
pub enum SegKey {
    Name(String),
    Wildcard,
}

/// One dotted path segment.
#[derive(Clone, PartialEq, Debug)]
pub struct Segment {
    pub key: SegKey,
    pub pred: Option<Pred>,
}

/// A dotted path over the virtual tree.
#[derive(Clone, PartialEq, Debug)]
pub struct Path {
    pub segments: Vec<Segment>,
}

/// Aggregation window: a trailing window ending now, or an explicit
/// `[from, to)` span.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum WindowSpec {
    Trailing(SimTime),
    Span(SimTime, SimTime),
}

/// A parsed DQL expression.
#[derive(Clone, PartialEq, Debug)]
pub enum Expr {
    Path(Path),
    Agg {
        func: AggFunc,
        path: Path,
        window: Option<WindowSpec>,
    },
}

impl Expr {
    /// Parse source text into an expression; every malformed input is
    /// a typed [`DalekError::InvalidQuery`].
    pub fn parse(src: &str) -> Result<Expr, DalekError> {
        let mut p = Parser {
            s: src.as_bytes(),
            i: 0,
        };
        p.ws();
        let expr = p.expr()?;
        p.ws();
        if p.i < p.s.len() {
            return Err(p.err(format!(
                "unexpected trailing input at byte {}",
                p.i
            )));
        }
        Ok(expr)
    }
}

fn invalid(msg: impl Into<String>) -> DalekError {
    DalekError::InvalidQuery(msg.into())
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> DalekError {
        invalid(msg)
    }

    fn ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, b: u8) -> bool {
        if self.peek() == Some(b) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), DalekError> {
        if self.eat(b) {
            Ok(())
        } else {
            Err(self.err(format!(
                "expected `{}` at byte {}",
                b as char, self.i
            )))
        }
    }

    fn is_ident_byte(b: u8) -> bool {
        b.is_ascii_alphanumeric() || b == b'_' || b == b'-'
    }

    fn ident(&mut self) -> Result<String, DalekError> {
        let start = self.i;
        while self.peek().map(Self::is_ident_byte).unwrap_or(false) {
            self.i += 1;
        }
        if self.i == start {
            return Err(self.err(format!("expected an identifier at byte {start}")));
        }
        Ok(String::from_utf8_lossy(&self.s[start..self.i]).into_owned())
    }

    fn expr(&mut self) -> Result<Expr, DalekError> {
        // lookahead: `func(` opens an aggregate; anything else is a path
        let mark = self.i;
        if self.peek().map(Self::is_ident_byte).unwrap_or(false) {
            let name = self.ident()?;
            let after_ident = self.i;
            self.ws();
            if self.eat(b'(') {
                let func = AggFunc::from_str(&name).ok_or_else(|| {
                    self.err(format!(
                        "unknown aggregation `{name}` (sum | mean | min | max | count)"
                    ))
                })?;
                return self.agg_body(func);
            }
            // not a call: rewind past the whitespace and parse as a path
            self.i = after_ident;
            self.i = mark;
        }
        Ok(Expr::Path(self.path()?))
    }

    fn agg_body(&mut self, func: AggFunc) -> Result<Expr, DalekError> {
        self.ws();
        let path = self.path()?;
        self.ws();
        let window = if self.eat(b',') {
            self.ws();
            Some(self.window()?)
        } else {
            None
        };
        self.ws();
        self.expect(b')')?;
        if func == AggFunc::Count && window.is_some() {
            return Err(self.err("count() takes no window"));
        }
        Ok(Expr::Agg { func, path, window })
    }

    fn window(&mut self) -> Result<WindowSpec, DalekError> {
        let key = self.ident()?;
        self.ws();
        self.expect(b'=')?;
        self.ws();
        match key.as_str() {
            "window" => Ok(WindowSpec::Trailing(self.duration()?)),
            "from" => {
                let from = self.duration()?;
                self.ws();
                self.expect(b',')?;
                self.ws();
                let key2 = self.ident()?;
                if key2 != "to" {
                    return Err(self.err(format!("expected `to=`, got `{key2}`")));
                }
                self.ws();
                self.expect(b'=')?;
                self.ws();
                let to = self.duration()?;
                if to <= from {
                    return Err(self.err(format!(
                        "window span is empty: from={from} to={to}"
                    )));
                }
                Ok(WindowSpec::Span(from, to))
            }
            other => Err(self.err(format!(
                "unknown window argument `{other}` (window= | from=, to=)"
            ))),
        }
    }

    fn path(&mut self) -> Result<Path, DalekError> {
        let mut segments = vec![self.segment()?];
        while self.eat(b'.') {
            segments.push(self.segment()?);
        }
        Ok(Path { segments })
    }

    fn segment(&mut self) -> Result<Segment, DalekError> {
        let key = if self.eat(b'*') {
            SegKey::Wildcard
        } else {
            SegKey::Name(self.ident()?)
        };
        let pred = if self.eat(b'[') {
            self.ws();
            let field = self.ident()?;
            self.ws();
            let op = self.cmp_op()?;
            self.ws();
            let value = self.literal()?;
            self.ws();
            self.expect(b']')?;
            Some(Pred { field, op, value })
        } else {
            None
        };
        Ok(Segment { key, pred })
    }

    fn cmp_op(&mut self) -> Result<CmpOp, DalekError> {
        if self.eat(b'!') {
            self.expect(b'=')?;
            return Ok(CmpOp::Ne);
        }
        if self.eat(b'<') {
            return Ok(if self.eat(b'=') { CmpOp::Le } else { CmpOp::Lt });
        }
        if self.eat(b'>') {
            return Ok(if self.eat(b'=') { CmpOp::Ge } else { CmpOp::Gt });
        }
        if self.eat(b'=') {
            return Ok(CmpOp::Eq);
        }
        Err(self.err(format!(
            "expected a comparison operator at byte {}",
            self.i
        )))
    }

    fn literal(&mut self) -> Result<Literal, DalekError> {
        match self.peek() {
            Some(b'"') => Ok(Literal::Str(self.string()?)),
            Some(b't') | Some(b'f') => {
                let mark = self.i;
                let word = self.ident()?;
                match word.as_str() {
                    "true" => Ok(Literal::Bool(true)),
                    "false" => Ok(Literal::Bool(false)),
                    _ => Err(self.err(format!(
                        "invalid literal `{word}` at byte {mark} \
                         (string, number, true or false)"
                    ))),
                }
            }
            _ => Ok(Literal::Num(self.number()?)),
        }
    }

    fn string(&mut self) -> Result<String, DalekError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string literal")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        _ => return Err(self.err("invalid string escape (\\\" or \\\\)")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // multi-byte UTF-8 passes through byte by byte
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.s.len() && (self.s[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(&String::from_utf8_lossy(&self.s[start..self.i]));
                }
            }
        }
    }

    fn number(&mut self) -> Result<f64, DalekError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|b| b.is_ascii_digit() || matches!(b, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            // a sign is only part of the number straight after an exponent
            if matches!(self.peek(), Some(b'+') | Some(b'-'))
                && !matches!(self.s.get(self.i - 1), Some(b'e') | Some(b'E'))
            {
                break;
            }
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.s[start..self.i])
            .map_err(|_| self.err("invalid number"))?;
        let v: f64 = text
            .parse()
            .map_err(|_| self.err(format!("invalid number `{text}` at byte {start}")))?;
        if !v.is_finite() {
            return Err(self.err(format!("number `{text}` is not finite")));
        }
        Ok(v)
    }

    /// A duration: number + optional unit (ns | us | ms | s | m | h);
    /// a bare number means seconds. Rounded to the ns grid.
    fn duration(&mut self) -> Result<SimTime, DalekError> {
        let v = self.number()?;
        if v < 0.0 {
            return Err(self.err(format!("duration {v} must be non-negative")));
        }
        let unit_ns: f64 = if self.peek().map(Self::is_ident_byte).unwrap_or(false) {
            let unit = self.ident()?;
            match unit.as_str() {
                "ns" => 1.0,
                "us" => 1e3,
                "ms" => 1e6,
                "s" => 1e9,
                "m" => 60e9,
                "h" => 3600e9,
                other => {
                    return Err(self.err(format!(
                        "unknown duration unit `{other}` (ns | us | ms | s | m | h)"
                    )))
                }
            }
        } else {
            1e9
        };
        let ns = v * unit_ns;
        if !ns.is_finite() || ns > u64::MAX as f64 {
            return Err(self.err(format!("duration {v} is out of range")));
        }
        Ok(SimTime::from_ns(ns.round() as u64))
    }
}

/// Canonical duration spelling: the largest unit that divides the
/// ns value exactly, so `Display` → parse is lossless.
pub(crate) fn dur_str(t: SimTime) -> String {
    let ns = t.as_ns();
    if ns % 1_000_000_000 == 0 {
        format!("{}s", ns / 1_000_000_000)
    } else if ns % 1_000_000 == 0 {
        format!("{}ms", ns / 1_000_000)
    } else if ns % 1_000 == 0 {
        format!("{}us", ns / 1_000)
    } else {
        format!("{ns}ns")
    }
}

impl fmt::Display for Literal {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Literal::Num(v) => write!(f, "{v}"),
            Literal::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Literal::Bool(b) => write!(f, "{b}"),
        }
    }
}

impl fmt::Display for Segment {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.key {
            SegKey::Name(n) => write!(f, "{n}")?,
            SegKey::Wildcard => write!(f, "*")?,
        }
        if let Some(p) = &self.pred {
            write!(f, "[{}{}{}]", p.field, p.op.as_str(), p.value)?;
        }
        Ok(())
    }
}

impl fmt::Display for Path {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (k, seg) in self.segments.iter().enumerate() {
            if k > 0 {
                write!(f, ".")?;
            }
            write!(f, "{seg}")?;
        }
        Ok(())
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Path(p) => write!(f, "{p}"),
            Expr::Agg { func, path, window } => {
                write!(f, "{}({path}", func.as_str())?;
                match window {
                    None => {}
                    Some(WindowSpec::Trailing(w)) => write!(f, ", window={}", dur_str(*w))?,
                    Some(WindowSpec::Span(a, b)) => {
                        write!(f, ", from={}, to={}", dur_str(*a), dur_str(*b))?
                    }
                }
                write!(f, ")")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Expr {
        Expr::parse(s).unwrap_or_else(|e| panic!("{s}: {e}"))
    }

    #[test]
    fn issue_examples_parse() {
        let e = parse("nodes.*.power.watts");
        let Expr::Path(p) = &e else { panic!("path") };
        assert_eq!(p.segments.len(), 4);
        assert_eq!(p.segments[1].key, SegKey::Wildcard);

        let e = parse(r#"jobs[user="az5"].energy_j"#);
        let Expr::Path(p) = &e else { panic!("path") };
        let pred = p.segments[0].pred.as_ref().unwrap();
        assert_eq!(pred.field, "user");
        assert_eq!(pred.op, CmpOp::Eq);
        assert_eq!(pred.value, Literal::Str("az5".into()));

        let e = parse("sum(partitions.gpu.queue.depth)");
        assert!(matches!(
            e,
            Expr::Agg {
                func: AggFunc::Sum,
                window: None,
                ..
            }
        ));

        let e = parse(r#"mean(nodes[partition="gpu"].power.watts, window=60s)"#);
        let Expr::Agg { func, window, .. } = &e else {
            panic!("agg")
        };
        assert_eq!(*func, AggFunc::Mean);
        assert_eq!(*window, Some(WindowSpec::Trailing(SimTime::from_secs(60))));
    }

    #[test]
    fn canonical_display_round_trips() {
        for src in [
            "nodes.*.power.watts",
            r#"jobs[user="az5"].energy_j"#,
            "sum(partitions.gpu.queue.depth)",
            r#"mean(nodes[partition="gpu"].power.watts, window=60s)"#,
            "count(nodes[capped=true])",
            "min(nodes.*.power.watts, from=10s, to=70s)",
            "max(nodes[boots>=2].power.energy_j)",
            r#"jobs[state!="completed"].id"#,
            "sum(nodes.*.power.energy_j, window=500ms)",
            "cluster.watts",
        ] {
            let a = parse(src);
            let shown = a.to_string();
            let b = parse(&shown);
            assert_eq!(a, b, "{src} -> {shown}");
            assert_eq!(shown, b.to_string(), "display must be idempotent");
        }
    }

    #[test]
    fn whitespace_is_tolerated_and_canonicalized() {
        let a = parse("  mean( nodes . * . power . watts ,  window = 2m )  ");
        assert_eq!(a.to_string(), "mean(nodes.*.power.watts, window=120s)");
        let b = parse(&a.to_string());
        assert_eq!(a, b);
    }

    #[test]
    fn durations_pick_the_largest_exact_unit() {
        assert_eq!(dur_str(SimTime::from_secs(3600)), "3600s");
        assert_eq!(dur_str(SimTime::from_ms(1500)), "1500ms");
        assert_eq!(dur_str(SimTime::from_us(7)), "7us");
        assert_eq!(dur_str(SimTime::from_ns(3)), "3ns");
        // all unit spellings land on the ns grid exactly
        let Expr::Agg { window, .. } = parse("sum(a, window=1h)") else {
            panic!()
        };
        assert_eq!(window, Some(WindowSpec::Trailing(SimTime::from_hours(1))));
        let Expr::Agg { window, .. } = parse("sum(a, window=250us)") else {
            panic!()
        };
        assert_eq!(window, Some(WindowSpec::Trailing(SimTime::from_us(250))));
    }

    #[test]
    fn malformed_inputs_are_typed_errors() {
        for src in [
            "",
            ".",
            "nodes.",
            ".nodes",
            "nodes..watts",
            "nodes[",
            "nodes[x]",
            "nodes[x=]",
            "nodes[x=1",
            "nodes[=1]",
            "nodes[x~1]",
            "sum(",
            "sum()",
            "sum(nodes",
            "sum(nodes,)",
            "sum(nodes, window)",
            "sum(nodes, window=)",
            "sum(nodes, window=5parsecs)",
            "sum(nodes, from=1s)",
            "sum(nodes, from=1s, till=2s)",
            "sum(nodes, from=5s, to=5s)",
            "count(nodes, window=5s)",
            "avg(nodes.*)",
            "frobnicate(x)",
            "nodes.*.watts trailing junk",
            "nodes[x=\"unterminated]",
            "nodes[x=\"bad\\escape\"]",
            "nodes[x=--3]",
            "nodes[x=1e999]",
            "sum(a, window=-5s)",
            "nodes[x=truish]",
        ] {
            match Expr::parse(src) {
                Err(DalekError::InvalidQuery(_)) => {}
                other => panic!("`{src}` must be InvalidQuery, got {other:?}"),
            }
        }
    }

    #[test]
    fn agg_names_are_valid_path_heads_without_parens() {
        // `sum` with no call syntax is just a segment named sum
        let e = parse("sum.count");
        let Expr::Path(p) = &e else { panic!("path") };
        assert_eq!(p.segments[0].key, SegKey::Name("sum".into()));
        assert_eq!(p.segments[1].key, SegKey::Name("count".into()));
        // but a non-aggregate call is an error
        assert!(matches!(
            Expr::parse("exterminate(nodes)"),
            Err(DalekError::InvalidQuery(_))
        ));
    }

    #[test]
    fn numeric_and_bool_predicates() {
        let e = parse("nodes[boots>2].name");
        let Expr::Path(p) = &e else { panic!() };
        let pred = p.segments[0].pred.as_ref().unwrap();
        assert_eq!(pred.op, CmpOp::Gt);
        assert_eq!(pred.value, Literal::Num(2.0));
        let e = parse("nodes[capped=false]");
        let Expr::Path(p) = &e else { panic!() };
        assert_eq!(
            p.segments[0].pred.as_ref().unwrap().value,
            Literal::Bool(false)
        );
        // scientific notation survives the round trip
        let e = parse("jobs[energy_j<1.5e6]");
        assert_eq!(parse(&e.to_string()), e);
    }

    #[test]
    fn string_escapes_round_trip() {
        let lit = Literal::Str("a\"b\\c".into());
        let p = Expr::Path(Path {
            segments: vec![Segment {
                key: SegKey::Name("jobs".into()),
                pred: Some(Pred {
                    field: "user".into(),
                    op: CmpOp::Eq,
                    value: lit,
                }),
            }],
        });
        let shown = p.to_string();
        assert_eq!(shown, r#"jobs[user="a\"b\\c"]"#);
        assert_eq!(parse(&shown), p);
    }
}
