//! The virtual tree DQL evaluates against.
//!
//! Nothing here is materialized: [`Tree`] is a lazy lookup interface —
//! "what is at this path" / "what are this node's children" — and
//! [`ClusterTree`] answers it by *projecting* the live cluster state
//! (scheduler indexes, quota accounts, flow-network link loads, the
//! sampler's closed-form rolling windows) on demand. Resolving
//! `nodes.*.power.watts` over a 16-node cluster costs 16 index reads,
//! not a snapshot.
//!
//! The admin schema:
//!
//! ```text
//! cluster.{watts, energy_j, measured_energy_j, jobs_pending,
//!          jobs_completed, now_s, faults_injected, fault_requeues,
//!          mtbf_s}
//! nodes.<name>.{name, partition, state, running, capped, boots,
//!               suspends, power.{watts, energy_j}, measured.energy_j,
//!               faults.{active, kind, param}}
//! jobs.<id>.{id, user, partition, state, nodes, energy_j, rate,
//!            submitted_s, started_s, finished_s, wait_s, run_s}
//! partitions.<name>.{name, nodes, running, watts, queue.depth}
//! quota.<user>.{time_budget_s, energy_budget_j, used_time_s,
//!               used_energy_j}
//! users.<user>.fairshare.{share, usage, priority}
//! net.{active_flows, completed_flows, delivered_bytes,
//!      fabric.{capacity_bps, used_bps},
//!      links.<host>.{up, down}.{capacity_bps, used_bps}}
//! ```
//!
//! Ordering is pinned for determinism: `nodes` children follow the
//! scheduler's node-index order (the same order every cluster-wide
//! float sum already uses), `jobs` follow ascending id, everything
//! else is name-sorted. Owner scoping is enforced *in the tree*: a
//! non-admin session only lists its own jobs and quota/fair-share
//! accounts, and a
//! direct path to another user's entry is a typed `AdminOnly` error —
//! the evaluator cannot leak what the tree refuses to show.
//!
//! Windowed leaves ([`Tree::windowed`]) answer from the closed-form
//! segment math (`node_rolling_mean_w` / `node_span_energy_j`) or the
//! probe stores' batched `window_energy_j` — never by materializing
//! samples.

use std::collections::{BTreeMap, BTreeSet};

use super::expr::WindowSpec;
use crate::api::error::DalekError;
use crate::api::protocol::job_state_str;
use crate::energy::api::EnergyApi;
use crate::energy::StreamingSampler;
use crate::net::{FlowNet, HostId, Topology};
use crate::power::PowerState;
use crate::sim::SimTime;
use crate::slurm::{JobId, NodeFault, Slurm};

/// A scalar value at a tree leaf.
#[derive(Clone, PartialEq, Debug)]
pub enum QueryValue {
    Num(f64),
    Str(String),
    Bool(bool),
    Null,
}

/// What lives at one tree path.
#[derive(Clone, PartialEq, Debug)]
pub enum TreeNode {
    /// an interior node: its children's names, in canonical order
    Interior(Vec<String>),
    Leaf(QueryValue),
}

/// Lazy lookup interface the evaluator walks.
pub trait Tree {
    /// What is at `path`? `None` = no such path. Errors are capability
    /// refusals (e.g. a non-admin reaching into another user's jobs).
    fn node(&self, path: &[String]) -> Result<Option<TreeNode>, DalekError>;

    /// A leaf's windowed value, if the leaf supports windows: `None`
    /// means "exists but not windowable" (the evaluator turns that
    /// into a typed error).
    fn windowed(&self, path: &[String], window: &WindowSpec)
        -> Result<Option<f64>, DalekError>;
}

fn power_state_str(s: PowerState) -> &'static str {
    match s {
        PowerState::Suspended => "suspended",
        PowerState::Booting { .. } => "booting",
        PowerState::Idle { .. } => "idle",
        PowerState::Allocated => "allocated",
        PowerState::Suspending { .. } => "suspending",
    }
}

fn names(items: &[&str]) -> Vec<String> {
    items.iter().map(|s| s.to_string()).collect()
}

// ---------------------------------------------------------------------------
// ClusterTree: the live projection

/// The live cluster projected as a [`Tree`], borrowing the read
/// surfaces the evaluator needs. Constructed per evaluation by
/// `ClusterApi` from disjoint field borrows; `scope` is the session's
/// login for owner scoping (`None` = admin, sees all).
pub struct ClusterTree<'a> {
    slurm: &'a Slurm,
    sampler: &'a StreamingSampler,
    energy: &'a EnergyApi,
    net: &'a FlowNet,
    topo: &'a Topology,
    now: SimTime,
    scope: Option<&'a str>,
}

impl<'a> ClusterTree<'a> {
    pub(crate) fn new(
        slurm: &'a Slurm,
        sampler: &'a StreamingSampler,
        energy: &'a EnergyApi,
        net: &'a FlowNet,
        topo: &'a Topology,
        now: SimTime,
        scope: Option<&'a str>,
    ) -> Self {
        Self {
            slurm,
            sampler,
            energy,
            net,
            topo,
            now,
            scope,
        }
    }

    /// Host names are FQDNs (`az5-a890m-0.dalek`); the tree uses the
    /// bare host part so names stay valid path idents.
    fn short_host(name: &str) -> &str {
        name.split('.').next().unwrap_or(name)
    }

    fn host_by_short(&self, short: &str) -> Option<HostId> {
        self.topo
            .hosts()
            .iter()
            .position(|h| Self::short_host(&h.name) == short)
            .map(HostId)
    }

    fn visible_job(&self, id: JobId) -> Result<Option<&crate::slurm::Job>, DalekError> {
        let Some(job) = self.slurm.job(id) else {
            return Ok(None);
        };
        if let Some(user) = self.scope {
            if job.spec.user != user {
                return Err(DalekError::AdminOnly);
            }
        }
        Ok(Some(job))
    }

    fn cluster_node(&self, rest: &[String]) -> Result<Option<TreeNode>, DalekError> {
        let leaf = |v: QueryValue| Ok(Some(TreeNode::Leaf(v)));
        match rest {
            [] => Ok(Some(TreeNode::Interior(names(&[
                "energy_j",
                "fault_requeues",
                "faults_injected",
                "jobs_completed",
                "jobs_pending",
                "measured_energy_j",
                "mtbf_s",
                "now_s",
                "watts",
            ])))),
            [k] => match k.as_str() {
                "energy_j" => leaf(QueryValue::Num(self.slurm.total_energy_j())),
                "fault_requeues" => {
                    leaf(QueryValue::Num(self.slurm.stats.fault_requeues as f64))
                }
                "faults_injected" => {
                    leaf(QueryValue::Num(self.slurm.stats.faults_injected as f64))
                }
                "jobs_completed" => {
                    leaf(QueryValue::Num(self.slurm.stats.completed as f64))
                }
                "jobs_pending" => leaf(QueryValue::Num(self.slurm.pending_count() as f64)),
                "measured_energy_j" => leaf(QueryValue::Num(self.energy.total_energy_j())),
                // observed mean time between failures over this run;
                // null until the first injection (not 0 — "no failures
                // yet" must not read as "fails constantly")
                "mtbf_s" => leaf(match self.slurm.stats.faults_injected {
                    0 => QueryValue::Null,
                    n => QueryValue::Num(self.now.as_secs_f64() / n as f64),
                }),
                "now_s" => leaf(QueryValue::Num(self.now.as_secs_f64())),
                "watts" => leaf(QueryValue::Num(self.slurm.cluster_watts())),
                _ => Ok(None),
            },
            _ => Ok(None),
        }
    }

    fn node_node(&self, rest: &[String]) -> Result<Option<TreeNode>, DalekError> {
        let leaf = |v: QueryValue| Ok(Some(TreeNode::Leaf(v)));
        let [name, rest @ ..] = rest else {
            // node-index order: the same order every cluster-wide sum
            // (watts, joules, rolling means) folds in
            let list = (0..self.slurm.node_count())
                .filter_map(|i| self.slurm.node_name(i).map(str::to_string))
                .collect();
            return Ok(Some(TreeNode::Interior(list)));
        };
        let Some(idx) = self.slurm.node_index(name) else {
            return Ok(None);
        };
        let info = self.slurm.node_info(idx);
        match rest {
            [] => Ok(Some(TreeNode::Interior(names(&[
                "boots",
                "capped",
                "faults",
                "measured",
                "name",
                "partition",
                "power",
                "running",
                "state",
                "suspends",
            ])))),
            [k] => match k.as_str() {
                "boots" => leaf(QueryValue::Num(info.boots as f64)),
                "capped" => leaf(QueryValue::Bool(self.slurm.node_capped(idx))),
                "faults" => Ok(Some(TreeNode::Interior(names(&[
                    "active", "kind", "param",
                ])))),
                "measured" => Ok(Some(TreeNode::Interior(names(&["energy_j"])))),
                "name" => leaf(QueryValue::Str(info.name)),
                "partition" => leaf(QueryValue::Str(info.partition)),
                "power" => Ok(Some(TreeNode::Interior(names(&["energy_j", "watts"])))),
                "running" => leaf(match info.running {
                    Some(j) => QueryValue::Num(j.0 as f64),
                    None => QueryValue::Null,
                }),
                "state" => leaf(QueryValue::Str(power_state_str(info.state).into())),
                "suspends" => leaf(QueryValue::Num(info.suspends as f64)),
                _ => Ok(None),
            },
            [k, l] => match (k.as_str(), l.as_str()) {
                ("power", "watts") => leaf(QueryValue::Num(info.watts)),
                ("power", "energy_j") => leaf(QueryValue::Num(info.energy_j)),
                // live `dalek::faults` state: whether an anomaly holds
                // the node, which kind, and its bound knob value (the
                // hang hold draw, brownout floor or throttle factor)
                ("faults", "active") => leaf(QueryValue::Bool(info.fault.is_some())),
                ("faults", "kind") => leaf(match info.fault {
                    Some(NodeFault::Crashed) => QueryValue::Str("crash".into()),
                    Some(NodeFault::Hung { .. }) => QueryValue::Str("hang".into()),
                    Some(NodeFault::Brownout { .. }) => QueryValue::Str("brownout".into()),
                    Some(NodeFault::Throttled { .. }) => QueryValue::Str("throttle".into()),
                    None => QueryValue::Null,
                }),
                ("faults", "param") => leaf(match info.fault {
                    Some(NodeFault::Hung { hold_w }) => QueryValue::Num(hold_w),
                    Some(NodeFault::Brownout { floor_w }) => QueryValue::Num(floor_w),
                    Some(NodeFault::Throttled { factor }) => QueryValue::Num(factor),
                    Some(NodeFault::Crashed) | None => QueryValue::Null,
                }),
                ("measured", "energy_j") => {
                    let j = self
                        .energy
                        .board(name)
                        .map(|b| b.total_energy_j())
                        .unwrap_or(0.0);
                    leaf(QueryValue::Num(j))
                }
                _ => Ok(None),
            },
            _ => Ok(None),
        }
    }

    fn job_node(&self, rest: &[String]) -> Result<Option<TreeNode>, DalekError> {
        let leaf = |v: QueryValue| Ok(Some(TreeNode::Leaf(v)));
        let opt_secs = |t: Option<SimTime>| match t {
            Some(t) => QueryValue::Num(t.as_secs_f64()),
            None => QueryValue::Null,
        };
        let [id, rest @ ..] = rest else {
            let list = self
                .slurm
                .jobs()
                .filter(|j| match self.scope {
                    Some(user) => j.spec.user == user,
                    None => true,
                })
                .map(|j| j.id.0.to_string())
                .collect();
            return Ok(Some(TreeNode::Interior(list)));
        };
        let Ok(id) = id.parse::<u64>() else {
            return Ok(None);
        };
        let Some(job) = self.visible_job(JobId(id))? else {
            return Ok(None);
        };
        match rest {
            [] => Ok(Some(TreeNode::Interior(names(&[
                "energy_j",
                "finished_s",
                "id",
                "nodes",
                "partition",
                "rate",
                "run_s",
                "started_s",
                "state",
                "submitted_s",
                "user",
                "wait_s",
            ])))),
            [k] => match k.as_str() {
                "energy_j" => leaf(QueryValue::Num(job.energy_j)),
                "finished_s" => leaf(opt_secs(job.finished)),
                "id" => leaf(QueryValue::Num(job.id.0 as f64)),
                "nodes" => leaf(QueryValue::Num(job.spec.nodes as f64)),
                "partition" => leaf(QueryValue::Str(job.spec.partition.clone())),
                "rate" => leaf(QueryValue::Num(job.rate)),
                "run_s" => leaf(opt_secs(job.run_time())),
                "started_s" => leaf(opt_secs(job.started)),
                "state" => leaf(QueryValue::Str(job_state_str(job.state).into())),
                "submitted_s" => leaf(QueryValue::Num(job.submitted.as_secs_f64())),
                "user" => leaf(QueryValue::Str(job.spec.user.clone())),
                "wait_s" => leaf(opt_secs(job.wait_time())),
                _ => Ok(None),
            },
            _ => Ok(None),
        }
    }

    fn partition_node(&self, rest: &[String]) -> Result<Option<TreeNode>, DalekError> {
        let leaf = |v: QueryValue| Ok(Some(TreeNode::Leaf(v)));
        let [name, rest @ ..] = rest else {
            let list = self.slurm.partitions().map(|(n, _)| n.to_string()).collect();
            return Ok(Some(TreeNode::Interior(list)));
        };
        let Some(indices) = self.slurm.partition_nodes(name) else {
            return Ok(None);
        };
        match rest {
            [] => Ok(Some(TreeNode::Interior(names(&[
                "name", "nodes", "queue", "running", "watts",
            ])))),
            [k] => match k.as_str() {
                "name" => leaf(QueryValue::Str(name.clone())),
                "nodes" => leaf(QueryValue::Num(indices.len() as f64)),
                "queue" => Ok(Some(TreeNode::Interior(names(&["depth"])))),
                "running" => {
                    let n = indices
                        .iter()
                        .filter(|&&i| self.slurm.node_info(i).running.is_some())
                        .count();
                    leaf(QueryValue::Num(n as f64))
                }
                "watts" => {
                    let w: f64 =
                        indices.iter().map(|&i| self.slurm.node_info(i).watts).sum();
                    leaf(QueryValue::Num(w))
                }
                _ => Ok(None),
            },
            [k, l] if k == "queue" && l == "depth" => {
                leaf(QueryValue::Num(self.slurm.partition_pending(name) as f64))
            }
            _ => Ok(None),
        }
    }

    fn quota_node(&self, rest: &[String]) -> Result<Option<TreeNode>, DalekError> {
        let leaf = |v: QueryValue| Ok(Some(TreeNode::Leaf(v)));
        let [user, rest @ ..] = rest else {
            let list = self
                .slurm
                .quota
                .accounts()
                .filter(|(u, _)| match self.scope {
                    Some(me) => *u == me,
                    None => true,
                })
                .map(|(u, _)| u.to_string())
                .collect();
            return Ok(Some(TreeNode::Interior(list)));
        };
        if let Some(me) = self.scope {
            if user != me {
                return Err(DalekError::AdminOnly);
            }
        }
        let Ok(a) = self.slurm.quota.account(user) else {
            return Ok(None);
        };
        match rest {
            [] => Ok(Some(TreeNode::Interior(names(&[
                "energy_budget_j",
                "time_budget_s",
                "used_energy_j",
                "used_time_s",
            ])))),
            [k] => match k.as_str() {
                "energy_budget_j" => leaf(QueryValue::Num(a.energy_budget_j)),
                "time_budget_s" => leaf(QueryValue::Num(a.time_budget_s)),
                "used_energy_j" => leaf(QueryValue::Num(a.used_energy_j)),
                "used_time_s" => leaf(QueryValue::Num(a.used_time_s)),
                _ => Ok(None),
            },
            _ => Ok(None),
        }
    }

    fn users_node(&self, rest: &[String]) -> Result<Option<TreeNode>, DalekError> {
        let leaf = |v: QueryValue| Ok(Some(TreeNode::Leaf(v)));
        let [user, rest @ ..] = rest else {
            let list = self
                .slurm
                .fairshare
                .accounts()
                .filter(|(u, _)| match self.scope {
                    Some(me) => *u == me,
                    None => true,
                })
                .map(|(u, _)| u.to_string())
                .collect();
            return Ok(Some(TreeNode::Interior(list)));
        };
        if let Some(me) = self.scope {
            if user != me {
                return Err(DalekError::AdminOnly);
            }
        }
        let Some(a) = self.slurm.fairshare.account(user) else {
            return Ok(None);
        };
        match rest {
            [] => Ok(Some(TreeNode::Interior(names(&["fairshare"])))),
            [k] if k == "fairshare" => Ok(Some(TreeNode::Interior(names(&[
                "priority", "share", "usage",
            ])))),
            [k, l] if k == "fairshare" => match l.as_str() {
                "priority" => leaf(QueryValue::Num(self.slurm.fairshare.user_priority(user))),
                "share" => leaf(QueryValue::Num(a.share)),
                "usage" => leaf(QueryValue::Num(a.usage)),
                _ => Ok(None),
            },
            _ => Ok(None),
        }
    }

    fn net_node(&self, rest: &[String]) -> Result<Option<TreeNode>, DalekError> {
        let leaf = |v: QueryValue| Ok(Some(TreeNode::Leaf(v)));
        match rest {
            [] => Ok(Some(TreeNode::Interior(names(&[
                "active_flows",
                "completed_flows",
                "delivered_bytes",
                "fabric",
                "links",
            ])))),
            [k] => match k.as_str() {
                "active_flows" => leaf(QueryValue::Num(self.net.active_flows() as f64)),
                "completed_flows" => {
                    leaf(QueryValue::Num(self.net.completed_flows as f64))
                }
                "delivered_bytes" => leaf(QueryValue::Num(self.net.delivered_bytes)),
                "fabric" => Ok(Some(TreeNode::Interior(names(&[
                    "capacity_bps",
                    "used_bps",
                ])))),
                "links" => {
                    let list = self
                        .topo
                        .hosts()
                        .iter()
                        .map(|h| Self::short_host(&h.name).to_string())
                        .collect();
                    Ok(Some(TreeNode::Interior(list)))
                }
                _ => Ok(None),
            },
            [k, rest @ ..] if k == "fabric" => match rest {
                [l] if l == "capacity_bps" => {
                    leaf(QueryValue::Num(self.net.fabric_capacity_bps()))
                }
                [l] if l == "used_bps" => leaf(QueryValue::Num(self.net.fabric_used_bps())),
                _ => Ok(None),
            },
            [k, host, rest @ ..] if k == "links" => {
                let Some(h) = self.host_by_short(host) else {
                    return Ok(None);
                };
                let (up, down) = self.net.host_load_bps(h);
                let cap = self.net.host_capacity_bps(h);
                match rest {
                    [] => Ok(Some(TreeNode::Interior(names(&["down", "up"])))),
                    [d] if d == "up" || d == "down" => Ok(Some(TreeNode::Interior(
                        names(&["capacity_bps", "used_bps"]),
                    ))),
                    [d, l] => {
                        let used = if d == "up" { up } else { down };
                        match (d.as_str(), l.as_str()) {
                            ("up" | "down", "capacity_bps") => leaf(QueryValue::Num(cap)),
                            ("up" | "down", "used_bps") => leaf(QueryValue::Num(used)),
                            _ => Ok(None),
                        }
                    }
                    _ => Ok(None),
                }
            }
            _ => Ok(None),
        }
    }
}

impl Tree for ClusterTree<'_> {
    fn node(&self, path: &[String]) -> Result<Option<TreeNode>, DalekError> {
        let [root, rest @ ..] = path else {
            return Ok(Some(TreeNode::Interior(names(&[
                "cluster",
                "jobs",
                "net",
                "nodes",
                "partitions",
                "quota",
                "users",
            ]))));
        };
        match root.as_str() {
            "cluster" => self.cluster_node(rest),
            "jobs" => self.job_node(rest),
            "net" => self.net_node(rest),
            "nodes" => self.node_node(rest),
            "partitions" => self.partition_node(rest),
            "quota" => self.quota_node(rest),
            "users" => self.users_node(rest),
            _ => Ok(None),
        }
    }

    fn windowed(
        &self,
        path: &[String],
        window: &WindowSpec,
    ) -> Result<Option<f64>, DalekError> {
        let span = |w: &WindowSpec| match *w {
            WindowSpec::Trailing(w) => (
                SimTime(self.now.as_ns().saturating_sub(w.as_ns())),
                self.now,
            ),
            WindowSpec::Span(a, b) => (a, b),
        };
        let strs: Vec<&str> = path.iter().map(|s| s.as_str()).collect();
        match strs.as_slice() {
            ["cluster", "watts"] => Ok(Some(match *window {
                WindowSpec::Trailing(w) => self.sampler.rolling_mean_w(w, self.now),
                WindowSpec::Span(a, b) => self.sampler.span_mean_w(a, b),
            })),
            ["cluster", "energy_j"] => {
                let (a, b) = span(window);
                Ok(Some(self.sampler.span_energy_j(a, b)))
            }
            ["nodes", name, "power", "watts"] => {
                let Some(idx) = self.slurm.node_index(name) else {
                    return Ok(None);
                };
                Ok(Some(match *window {
                    WindowSpec::Trailing(w) => {
                        self.sampler.node_rolling_mean_w(idx, w, self.now)
                    }
                    WindowSpec::Span(a, b) => {
                        let s = b.since(a).as_secs_f64();
                        if s <= 0.0 {
                            0.0
                        } else {
                            self.sampler.node_span_energy_j(idx, a, b) / s
                        }
                    }
                }))
            }
            ["nodes", name, "power", "energy_j"] => {
                let Some(idx) = self.slurm.node_index(name) else {
                    return Ok(None);
                };
                let (a, b) = span(window);
                Ok(Some(self.sampler.node_span_energy_j(idx, a, b)))
            }
            ["nodes", name, "measured", "energy_j"] => {
                let Ok(board) = self.energy.board(name) else {
                    return Ok(None);
                };
                let (a, b) = span(window);
                let mut total = 0.0;
                for p in 0..board.probe_count() {
                    if let Ok(store) = board.store(p as u8) {
                        total += store.window_energy_j(a, b);
                    }
                }
                Ok(Some(total))
            }
            _ => Ok(None),
        }
    }
}

// ---------------------------------------------------------------------------
// MemTree: a synthetic tree for tests and benches

/// A materialized in-memory [`Tree`], for parser/evaluator tests and
/// the `query_eval` bench (e.g. a synthetic 10k-node cluster). Leaves
/// are inserted by dotted path; interiors are implied.
#[derive(Default)]
pub struct MemTree {
    leaves: BTreeMap<String, QueryValue>,
    children: BTreeMap<String, BTreeSet<String>>,
}

impl MemTree {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a leaf at a dotted path, creating implied interiors.
    pub fn insert(&mut self, path: &str, value: QueryValue) {
        let parts: Vec<&str> = path.split('.').collect();
        let mut prefix = String::new();
        for (k, part) in parts.iter().enumerate() {
            self.children
                .entry(prefix.clone())
                .or_default()
                .insert(part.to_string());
            if k > 0 {
                prefix.push('.');
            }
            prefix.push_str(part);
        }
        self.leaves.insert(prefix, value);
    }
}

impl Tree for MemTree {
    fn node(&self, path: &[String]) -> Result<Option<TreeNode>, DalekError> {
        let key = path.join(".");
        if let Some(kids) = self.children.get(&key) {
            return Ok(Some(TreeNode::Interior(
                kids.iter().cloned().collect(),
            )));
        }
        Ok(self.leaves.get(&key).cloned().map(TreeNode::Leaf))
    }

    fn windowed(
        &self,
        path: &[String],
        _window: &WindowSpec,
    ) -> Result<Option<f64>, DalekError> {
        // synthetic: every numeric leaf answers windows with its value
        let key = path.join(".");
        Ok(match self.leaves.get(&key) {
            Some(QueryValue::Num(v)) => Some(*v),
            _ => None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memtree_projects_leaves_and_interiors() {
        let mut t = MemTree::new();
        t.insert("nodes.a.power.watts", QueryValue::Num(10.0));
        t.insert("nodes.b.power.watts", QueryValue::Num(20.0));
        t.insert("nodes.a.partition", QueryValue::Str("gpu".into()));
        let root = t.node(&[]).unwrap().unwrap();
        assert_eq!(root, TreeNode::Interior(vec!["nodes".into()]));
        let nodes = t.node(&["nodes".into()]).unwrap().unwrap();
        assert_eq!(
            nodes,
            TreeNode::Interior(vec!["a".into(), "b".into()])
        );
        let leaf = t
            .node(&["nodes".into(), "b".into(), "power".into(), "watts".into()])
            .unwrap()
            .unwrap();
        assert_eq!(leaf, TreeNode::Leaf(QueryValue::Num(20.0)));
        assert_eq!(t.node(&["nope".into()]).unwrap(), None);
    }
}
