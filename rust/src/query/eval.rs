//! The DQL evaluator: resolve a path expression against a [`Tree`],
//! then shape or aggregate the result.
//!
//! Resolution walks segments left to right over a frontier of
//! candidate paths:
//!
//! * a plain name extends every candidate by one level;
//! * `*` expands a candidate into its children (in the tree's
//!   canonical order — determinism rides on this);
//! * a `[field op literal]` predicate expands into children and keeps
//!   those whose `field` leaf matches, so `jobs[user="az5"]` and
//!   `jobs.*[user="az5"]` are the same set.
//!
//! A plain path that resolves to nothing is a typed `InvalidQuery`
//! ("no such path"); a *filtered* path (wildcard or predicate
//! involved) may legitimately resolve to an empty set — `sum` and
//! `count` answer 0, `mean`/`min`/`max` answer null.
//!
//! Shapes: one unfiltered leaf → `Scalar`; a set of leaves →
//! `Vector` (dotted path → value); a set of interior nodes → `Table`
//! (one row per node, columns = its scalar-leaf children). Aggregates
//! always produce a `Scalar`; windowed aggregates ask the tree's
//! closed-form [`Tree::windowed`] leaves instead of the instantaneous
//! values.

use super::expr::{AggFunc, CmpOp, Expr, Literal, Path, SegKey, WindowSpec};
use super::tree::{QueryValue, Tree, TreeNode};
use crate::api::error::DalekError;
use crate::util::json::Json;

/// The typed result of a query evaluation.
#[derive(Clone, PartialEq, Debug)]
pub enum QueryOutput {
    Scalar(QueryValue),
    /// resolved leaf paths with their values, in resolution order
    Vector(Vec<(String, QueryValue)>),
    /// resolved interior nodes as rows; `columns` are the scalar-leaf
    /// children of the first row (missing cells are null)
    Table {
        columns: Vec<String>,
        rows: Vec<(String, Vec<QueryValue>)>,
    },
}

fn invalid(msg: impl Into<String>) -> DalekError {
    DalekError::InvalidQuery(msg.into())
}

/// Resolve + shape/aggregate: the whole evaluation.
pub fn eval(tree: &dyn Tree, expr: &Expr) -> Result<QueryOutput, DalekError> {
    match expr {
        Expr::Path(path) => {
            let r = resolve(tree, path)?;
            shape(tree, r)
        }
        Expr::Agg { func, path, window } => {
            let r = resolve(tree, path)?;
            aggregate(tree, r, *func, window.as_ref())
        }
    }
}

struct Resolved {
    /// resolved candidate paths, in resolution order
    paths: Vec<Vec<String>>,
    /// whether a wildcard or predicate was involved (empty is then a
    /// legitimate answer rather than a "no such path" error)
    filtered: bool,
    display: String,
}

fn resolve(tree: &dyn Tree, path: &Path) -> Result<Resolved, DalekError> {
    let mut frontier: Vec<Vec<String>> = vec![Vec::new()];
    let mut filtered = false;
    for seg in &path.segments {
        let mut next: Vec<Vec<String>> = Vec::new();
        match &seg.key {
            SegKey::Name(name) => {
                for p in &frontier {
                    let mut q = p.clone();
                    q.push(name.clone());
                    if tree.node(&q)?.is_some() {
                        next.push(q);
                    } else if !filtered && seg.pred.is_none() {
                        return Err(invalid(format!("no such path: `{}`", q.join("."))));
                    }
                }
            }
            SegKey::Wildcard => {
                filtered = true;
                for p in &frontier {
                    if let Some(TreeNode::Interior(kids)) = tree.node(p)? {
                        for kid in kids {
                            let mut q = p.clone();
                            q.push(kid);
                            next.push(q);
                        }
                    }
                }
            }
        }
        if let Some(pred) = &seg.pred {
            filtered = true;
            // the predicate selects among the children of the set the
            // key resolved (so `jobs[user="x"]` filters jobs' children)
            let base = std::mem::take(&mut next);
            for p in &base {
                if let Some(TreeNode::Interior(kids)) = tree.node(p)? {
                    for kid in kids {
                        let mut q = p.clone();
                        q.push(kid);
                        if pred_matches(tree, &q, pred)? {
                            next.push(q);
                        }
                    }
                }
            }
        }
        frontier = next;
    }
    if frontier.is_empty() && !filtered {
        return Err(invalid(format!("no such path: `{path}`")));
    }
    Ok(Resolved {
        paths: frontier,
        filtered,
        display: path.to_string(),
    })
}

fn pred_matches(
    tree: &dyn Tree,
    path: &[String],
    pred: &super::expr::Pred,
) -> Result<bool, DalekError> {
    let mut q = path.to_vec();
    q.push(pred.field.clone());
    // capability refusals inside a *filter* just exclude the candidate
    // (a non-admin filtering jobs must not fail on other users' rows)
    let node = match tree.node(&q) {
        Ok(n) => n,
        Err(DalekError::AdminOnly) => return Ok(false),
        Err(e) => return Err(e),
    };
    let Some(TreeNode::Leaf(v)) = node else {
        return Ok(false);
    };
    Ok(match (&v, &pred.value) {
        (QueryValue::Num(a), Literal::Num(b)) => match a.partial_cmp(b) {
            None => false,
            Some(ord) => cmp_holds(pred.op, ord),
        },
        (QueryValue::Str(a), Literal::Str(b)) => cmp_holds(pred.op, a.as_str().cmp(b.as_str())),
        (QueryValue::Bool(a), Literal::Bool(b)) => match pred.op {
            CmpOp::Eq => a == b,
            CmpOp::Ne => a != b,
            _ => {
                return Err(invalid(format!(
                    "boolean predicate `{}` supports only = and !=",
                    pred.field
                )))
            }
        },
        _ => false,
    })
}

fn cmp_holds(op: CmpOp, ord: std::cmp::Ordering) -> bool {
    use std::cmp::Ordering::*;
    match op {
        CmpOp::Eq => ord == Equal,
        CmpOp::Ne => ord != Equal,
        CmpOp::Lt => ord == Less,
        CmpOp::Le => ord != Greater,
        CmpOp::Gt => ord == Greater,
        CmpOp::Ge => ord != Less,
    }
}

fn shape(tree: &dyn Tree, r: Resolved) -> Result<QueryOutput, DalekError> {
    let mut leaves: Vec<(String, QueryValue)> = Vec::new();
    let mut interiors: Vec<Vec<String>> = Vec::new();
    for p in &r.paths {
        match tree.node(p)? {
            Some(TreeNode::Leaf(v)) => leaves.push((p.join("."), v)),
            Some(TreeNode::Interior(_)) => interiors.push(p.clone()),
            None => {}
        }
    }
    match (leaves.is_empty(), interiors.is_empty()) {
        (false, false) => Err(invalid(format!(
            "`{}` mixes leaf and interior results",
            r.display
        ))),
        (false, true) => {
            if !r.filtered && leaves.len() == 1 {
                Ok(QueryOutput::Scalar(leaves.pop().expect("len 1").1))
            } else {
                Ok(QueryOutput::Vector(leaves))
            }
        }
        (true, false) => table(tree, interiors),
        (true, true) => Ok(QueryOutput::Vector(Vec::new())),
    }
}

fn table(tree: &dyn Tree, rows_paths: Vec<Vec<String>>) -> Result<QueryOutput, DalekError> {
    // columns: the scalar-leaf children of the first row, in the
    // tree's canonical child order; other rows fill missing cells
    // with null
    let mut columns: Vec<String> = Vec::new();
    if let Some(TreeNode::Interior(kids)) = tree.node(&rows_paths[0])? {
        for kid in kids {
            let mut q = rows_paths[0].clone();
            q.push(kid.clone());
            if let Some(TreeNode::Leaf(_)) = tree.node(&q)? {
                columns.push(kid);
            }
        }
    }
    let mut rows = Vec::with_capacity(rows_paths.len());
    for p in &rows_paths {
        let mut cells = Vec::with_capacity(columns.len());
        for c in &columns {
            let mut q = p.clone();
            q.push(c.clone());
            cells.push(match tree.node(&q)? {
                Some(TreeNode::Leaf(v)) => v,
                _ => QueryValue::Null,
            });
        }
        rows.push((p.join("."), cells));
    }
    Ok(QueryOutput::Table { columns, rows })
}

fn aggregate(
    tree: &dyn Tree,
    r: Resolved,
    func: AggFunc,
    window: Option<&WindowSpec>,
) -> Result<QueryOutput, DalekError> {
    if func == AggFunc::Count {
        return Ok(QueryOutput::Scalar(QueryValue::Num(r.paths.len() as f64)));
    }
    // collect the numeric inputs, in resolution order (float sums are
    // order-sensitive; resolution order == the tree's canonical order)
    let mut values: Vec<f64> = Vec::with_capacity(r.paths.len());
    for p in &r.paths {
        let v = match window {
            Some(w) => tree.windowed(p, w)?.ok_or_else(|| {
                invalid(format!("`{}` is not windowable", p.join(".")))
            })?,
            None => match tree.node(p)? {
                Some(TreeNode::Leaf(QueryValue::Num(v))) => v,
                Some(TreeNode::Leaf(_)) | Some(TreeNode::Interior(_)) => {
                    return Err(invalid(format!(
                        "`{}` is not a numeric leaf",
                        p.join(".")
                    )))
                }
                None => continue,
            },
        };
        values.push(v);
    }
    let out = match func {
        AggFunc::Sum => QueryValue::Num(values.iter().sum()),
        AggFunc::Mean => {
            if values.is_empty() {
                QueryValue::Null
            } else {
                QueryValue::Num(values.iter().sum::<f64>() / values.len() as f64)
            }
        }
        AggFunc::Min => values
            .iter()
            .copied()
            .reduce(f64::min)
            .map(QueryValue::Num)
            .unwrap_or(QueryValue::Null),
        AggFunc::Max => values
            .iter()
            .copied()
            .reduce(f64::max)
            .map(QueryValue::Num)
            .unwrap_or(QueryValue::Null),
        AggFunc::Count => unreachable!("handled above"),
    };
    Ok(QueryOutput::Scalar(out))
}

// ---------------------------------------------------------------------------
// JSON projection (shared by Response::QueryResult and query events)

/// A leaf value as wire JSON.
pub fn value_json(v: &QueryValue) -> Json {
    match v {
        QueryValue::Num(x) => Json::from(*x),
        QueryValue::Str(s) => Json::from(s.as_str()),
        QueryValue::Bool(b) => Json::from(*b),
        QueryValue::Null => Json::Null,
    }
}

/// A query result as wire JSON: `{"kind": "scalar" | "vector" |
/// "table", ...}` — the same encoding on the response path and the
/// standing-query event path (delta suppression compares these).
pub fn output_json(out: &QueryOutput) -> Json {
    match out {
        QueryOutput::Scalar(v) => Json::object([
            ("kind", Json::from("scalar")),
            ("value", value_json(v)),
        ]),
        QueryOutput::Vector(items) => Json::object([
            ("kind", Json::from("vector")),
            (
                "items",
                Json::array(items.iter().map(|(p, v)| {
                    Json::object([("path", Json::from(p.as_str())), ("value", value_json(v))])
                })),
            ),
        ]),
        QueryOutput::Table { columns, rows } => Json::object([
            ("kind", Json::from("table")),
            (
                "columns",
                Json::array(columns.iter().map(|c| Json::from(c.as_str()))),
            ),
            (
                "rows",
                Json::array(rows.iter().map(|(p, cells)| {
                    Json::object([
                        ("path", Json::from(p.as_str())),
                        ("values", Json::array(cells.iter().map(value_json))),
                    ])
                })),
            ),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::tree::MemTree;

    fn farm() -> MemTree {
        let mut t = MemTree::new();
        for (n, part, w, capped) in [
            ("n0", "gpu", 100.0, true),
            ("n1", "gpu", 50.0, false),
            ("n2", "cpu", 25.0, false),
        ] {
            t.insert(&format!("nodes.{n}.partition"), QueryValue::Str(part.into()));
            t.insert(&format!("nodes.{n}.power.watts"), QueryValue::Num(w));
            t.insert(&format!("nodes.{n}.capped"), QueryValue::Bool(capped));
        }
        t
    }

    fn run(t: &MemTree, src: &str) -> QueryOutput {
        eval(t, &Expr::parse(src).unwrap()).unwrap_or_else(|e| panic!("{src}: {e}"))
    }

    #[test]
    fn scalar_vector_and_aggregates() {
        let t = farm();
        assert_eq!(
            run(&t, "nodes.n0.power.watts"),
            QueryOutput::Scalar(QueryValue::Num(100.0))
        );
        let QueryOutput::Vector(v) = run(&t, "nodes.*.power.watts") else {
            panic!("vector");
        };
        assert_eq!(v.len(), 3);
        assert_eq!(v[0].0, "nodes.n0.power.watts");
        assert_eq!(
            run(&t, "sum(nodes.*.power.watts)"),
            QueryOutput::Scalar(QueryValue::Num(175.0))
        );
        assert_eq!(
            run(&t, "min(nodes.*.power.watts)"),
            QueryOutput::Scalar(QueryValue::Num(25.0))
        );
        assert_eq!(
            run(&t, "count(nodes.*)"),
            QueryOutput::Scalar(QueryValue::Num(3.0))
        );
    }

    #[test]
    fn predicates_filter_children() {
        let t = farm();
        assert_eq!(
            run(&t, r#"mean(nodes[partition="gpu"].power.watts)"#),
            QueryOutput::Scalar(QueryValue::Num(75.0))
        );
        assert_eq!(
            run(&t, "count(nodes[capped=true])"),
            QueryOutput::Scalar(QueryValue::Num(1.0))
        );
        assert_eq!(
            run(&t, "count(nodes[power=1])"), // field is not a leaf
            QueryOutput::Scalar(QueryValue::Num(0.0))
        );
        // numeric comparisons
        assert_eq!(
            run(&t, "count(nodes.*[watts>30])"), // missing field -> none
            QueryOutput::Scalar(QueryValue::Num(0.0))
        );
        // wildcard + pred filters the same set the bare pred does
        assert_eq!(
            run(&t, r#"count(nodes.*[partition!="gpu"])"#),
            run(&t, r#"count(nodes[partition!="gpu"])"#),
        );
    }

    #[test]
    fn empty_filters_and_missing_paths() {
        let t = farm();
        // filtered-empty is an answer, not an error
        assert_eq!(
            run(&t, r#"sum(nodes[partition="tpu"].power.watts)"#),
            QueryOutput::Scalar(QueryValue::Num(0.0))
        );
        assert_eq!(
            run(&t, r#"mean(nodes[partition="tpu"].power.watts)"#),
            QueryOutput::Scalar(QueryValue::Null)
        );
        assert_eq!(run(&t, r#"nodes[partition="tpu"]"#), QueryOutput::Vector(vec![]));
        // a plain path that goes nowhere is typed
        assert!(matches!(
            eval(&t, &Expr::parse("nodes.n9.power.watts").unwrap()),
            Err(DalekError::InvalidQuery(_))
        ));
        assert!(matches!(
            eval(&t, &Expr::parse("sum(nodes.n0.nope)").unwrap()),
            Err(DalekError::InvalidQuery(_))
        ));
    }

    #[test]
    fn tables_project_interior_rows() {
        let t = farm();
        let QueryOutput::Table { columns, rows } = run(&t, r#"nodes[partition="gpu"]"#)
        else {
            panic!("table");
        };
        assert_eq!(columns, vec!["capped", "partition"]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, "nodes.n0");
        assert_eq!(rows[0].1[0], QueryValue::Bool(true));
        // single unfiltered interior is still a table
        let QueryOutput::Table { rows, .. } = run(&t, "nodes.n2") else {
            panic!("table");
        };
        assert_eq!(rows.len(), 1);
    }

    #[test]
    fn windowed_aggregates_use_the_window_surface() {
        let t = farm();
        assert_eq!(
            run(&t, "sum(nodes.*.power.watts, window=60s)"),
            QueryOutput::Scalar(QueryValue::Num(175.0))
        );
        // a non-numeric leaf refuses windows, typed
        assert!(matches!(
            eval(&t, &Expr::parse("sum(nodes.*.partition, window=60s)").unwrap()),
            Err(DalekError::InvalidQuery(_))
        ));
    }

    #[test]
    fn bool_predicates_reject_orderings() {
        let t = farm();
        assert!(matches!(
            eval(&t, &Expr::parse("count(nodes[capped>false])").unwrap()),
            Err(DalekError::InvalidQuery(_))
        ));
    }

    #[test]
    fn output_json_shapes() {
        let j = output_json(&QueryOutput::Scalar(QueryValue::Num(2.5)));
        assert_eq!(j.to_string(), r#"{"kind":"scalar","value":2.5}"#);
        let j = output_json(&QueryOutput::Vector(vec![(
            "a.b".into(),
            QueryValue::Bool(true),
        )]));
        assert_eq!(
            j.to_string(),
            r#"{"items":[{"path":"a.b","value":true}],"kind":"vector"}"#
        );
    }
}
