//! DQL — the dalek query language over cluster state and rolling
//! telemetry.
//!
//! The paper's 1 kSPS milliwatt-resolution measurement plane only pays
//! off if operators can *ask questions* of it. DQL is the server-side
//! answer: opath-style path expressions with wildcards, predicates and
//! aggregation, evaluated against a **virtual tree** projected lazily
//! from live state — scheduler indexes, quota accounts, flow-network
//! link loads, and the sampler's closed-form rolling windows. No
//! samples are materialized and no cluster state is cloned to answer
//! a query.
//!
//! ```text
//! nodes.*.power.watts
//! jobs[user="az5"].energy_j
//! sum(partitions.az5-a890m.queue.depth)
//! mean(nodes[partition="az5-a890m"].power.watts, window=60s)
//! count(nodes[capped=true])
//! ```
//!
//! * [`expr`] — the AST, parser and canonical `Display`;
//! * [`tree`] — the [`Tree`] lookup trait, the live [`ClusterTree`]
//!   projection and the synthetic [`MemTree`];
//! * [`eval`] — resolution, shaping and aggregation into
//!   [`QueryOutput`];
//! * [`standing`] — standing-query registration state for the
//!   `query_events` channel (cadenced or edge-triggered, delta
//!   suppressed).
//!
//! Wire surface: `Request::Query { expr }` →
//! `Response::QueryResult`, and `subscribe` with
//! `channel = "query_events"` + an `expr`. Results are owner-scoped
//! through the capability model: non-admin sessions see only their own
//! jobs and quota account — enforced in the tree itself, so every
//! evaluation path inherits it.

pub mod eval;
pub mod expr;
pub mod standing;
pub mod tree;

pub use eval::{eval, output_json, value_json, QueryOutput};
pub use expr::{AggFunc, CmpOp, Expr, Literal, Path, Pred, SegKey, Segment, WindowSpec};
pub use tree::{ClusterTree, MemTree, QueryValue, Tree, TreeNode};
