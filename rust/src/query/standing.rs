//! Standing queries: registered expressions re-evaluated inside the
//! event pump and delivered as `query_events` deltas.
//!
//! A standing query is either *cadenced* (a `rate_hz` was given at
//! subscribe time: it re-evaluates on that deterministic sim-time
//! grid) or *edge-triggered* (no rate: it re-evaluates whenever the
//! pump observes job or power notices — the same edges the
//! `job_events`/`power_events` channels carry). Either way the result
//! is encoded to wire JSON and pushed into the session's bounded
//! outbox **only when it differs from the last delivery** — delta
//! suppression keeps a quiet cluster's channel quiet. Evaluation
//! errors (e.g. a path that stopped existing) are skipped silently:
//! the schedule must stay deterministic, and an error has no delta to
//! deliver.

use super::expr::Expr;
use crate::sim::SimTime;
use crate::util::json::Json;

/// One registered standing query of a session.
pub(crate) struct StandingQuery {
    pub expr: Expr,
    /// canonical spelling (what events echo back)
    pub canonical: String,
    /// `Some(period)` = cadenced; `None` = edge-triggered
    pub period: Option<SimTime>,
    /// next due time on the cadence grid (unused when edge-triggered)
    pub next_due: SimTime,
    /// last delivered wire encoding, for delta suppression
    pub last: Option<Json>,
}

impl StandingQuery {
    pub fn new(expr: Expr, period: Option<SimTime>, now: SimTime) -> Self {
        let canonical = expr.to_string();
        let next_due = match period {
            Some(p) => now + p,
            None => now,
        };
        Self {
            expr,
            canonical,
            period,
            next_due,
            last: None,
        }
    }

    /// Whether this query re-evaluates at `now` (`edge` = the pump saw
    /// job/power notices this round). Advances the cadence grid past
    /// `now` when due, so a long stride between pumps fires once, not
    /// once per missed grid point.
    pub fn due(&mut self, now: SimTime, edge: bool) -> bool {
        match self.period {
            None => edge,
            Some(p) => {
                if now < self.next_due {
                    return false;
                }
                while self.next_due <= now {
                    self.next_due = self.next_due + p;
                }
                true
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::expr::Expr;

    #[test]
    fn cadence_fires_once_per_stride_and_stays_on_grid() {
        let e = Expr::parse("cluster.watts").unwrap();
        let mut q = StandingQuery::new(e, Some(SimTime::from_secs(10)), SimTime::ZERO);
        assert!(!q.due(SimTime::from_secs(5), true), "not due yet");
        // a long stride covering many grid points fires exactly once
        assert!(q.due(SimTime::from_secs(35), false));
        assert_eq!(q.next_due, SimTime::from_secs(40));
        assert!(!q.due(SimTime::from_secs(39), true));
        assert!(q.due(SimTime::from_secs(40), false));
    }

    #[test]
    fn edge_triggered_follows_edges_only() {
        let e = Expr::parse("cluster.watts").unwrap();
        let mut q = StandingQuery::new(e, None, SimTime::ZERO);
        assert!(!q.due(SimTime::from_secs(1), false));
        assert!(q.due(SimTime::from_secs(1), true));
    }
}
