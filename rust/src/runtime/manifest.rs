//! Typed view of `artifacts/manifest.json` (schema `hlo-text-v1`,
//! written by `python/compile/aot.py`).

use std::path::{Path, PathBuf};

use crate::util::json::{Json, JsonError};

/// Input element type of a payload argument.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Dtype {
    F32,
    Bf16,
    I8,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(Dtype::F32),
            "bf16" => Some(Dtype::Bf16),
            "i8" => Some(Dtype::I8),
            "i32" => Some(Dtype::I32),
            _ => None,
        }
    }

    pub fn size_bytes(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::Bf16 => 2,
            Dtype::I8 => 1,
        }
    }
}

/// One runtime input argument.
#[derive(Clone, Debug, PartialEq)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl InputSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled payload.
#[derive(Clone, Debug)]
pub struct PayloadMeta {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<InputSpec>,
    pub flops: u64,
    pub description: String,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub payloads: Vec<PayloadMeta>,
}

#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error(transparent)]
    Json(#[from] JsonError),
    #[error("manifest schema: {0}")]
    Schema(String),
}

impl Manifest {
    /// Load from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, ManifestError> {
        let dir = dir.as_ref().to_path_buf();
        let src = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&src, dir)
    }

    /// Parse manifest text (dir recorded for resolving payload files).
    pub fn parse(src: &str, dir: PathBuf) -> Result<Self, ManifestError> {
        let j = Json::parse(src)?;
        let schema = |m: &str| ManifestError::Schema(m.to_string());
        if j.get("format").and_then(Json::as_str) != Some("hlo-text-v1") {
            return Err(schema("format must be hlo-text-v1"));
        }
        let mut payloads = Vec::new();
        for p in j
            .get("payloads")
            .and_then(Json::as_arr)
            .ok_or_else(|| schema("missing payloads[]"))?
        {
            let name = p
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| schema("payload.name"))?
                .to_string();
            let file = dir.join(
                p.get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| schema("payload.file"))?,
            );
            let mut inputs = Vec::new();
            for i in p
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| schema("payload.inputs"))?
            {
                let shape = i
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| schema("input.shape"))?
                    .iter()
                    .map(|d| d.as_u64().map(|v| v as usize))
                    .collect::<Option<Vec<usize>>>()
                    .ok_or_else(|| schema("input.shape dims"))?;
                let dtype = i
                    .get("dtype")
                    .and_then(Json::as_str)
                    .and_then(Dtype::parse)
                    .ok_or_else(|| schema("input.dtype"))?;
                inputs.push(InputSpec { shape, dtype });
            }
            payloads.push(PayloadMeta {
                name,
                file,
                inputs,
                flops: p
                    .get("flops")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| schema("payload.flops"))?,
                description: p
                    .get("description")
                    .and_then(Json::as_str)
                    .unwrap_or_default()
                    .to_string(),
            });
        }
        Ok(Self { dir, payloads })
    }

    pub fn payload(&self, name: &str) -> Option<&PayloadMeta> {
        self.payloads.iter().find(|p| p.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
  "format": "hlo-text-v1",
  "payloads": [
    {"name": "gemm256", "file": "gemm256.hlo.txt",
     "inputs": [{"shape": [256, 256], "dtype": "f32"},
                {"shape": [256, 256], "dtype": "f32"}],
     "flops": 33554432, "description": "gemm", "sha256_16": "xx"},
    {"name": "dpa4", "file": "dpa4.hlo.txt",
     "inputs": [{"shape": [8, 8], "dtype": "i8"}],
     "flops": 1024, "description": "dpa", "sha256_16": "yy"}
  ]
}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.payloads.len(), 2);
        let g = m.payload("gemm256").unwrap();
        assert_eq!(g.flops, 33554432);
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.inputs[0].shape, vec![256, 256]);
        assert_eq!(g.inputs[0].dtype, Dtype::F32);
        assert_eq!(g.inputs[0].element_count(), 65536);
        assert_eq!(g.file, PathBuf::from("/tmp/a/gemm256.hlo.txt"));
        assert_eq!(m.payload("dpa4").unwrap().inputs[0].dtype, Dtype::I8);
        assert!(m.payload("nope").is_none());
    }

    #[test]
    fn rejects_bad_format() {
        let e = Manifest::parse(
            r#"{"format": "v0", "payloads": []}"#,
            PathBuf::from("."),
        );
        assert!(matches!(e, Err(ManifestError::Schema(_))));
    }

    #[test]
    fn rejects_missing_fields() {
        let e = Manifest::parse(
            r#"{"format": "hlo-text-v1", "payloads": [{"name": "x"}]}"#,
            PathBuf::from("."),
        );
        assert!(matches!(e, Err(ManifestError::Schema(_))));
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(Dtype::F32.size_bytes(), 4);
        assert_eq!(Dtype::Bf16.size_bytes(), 2);
        assert_eq!(Dtype::I8.size_bytes(), 1);
        assert_eq!(Dtype::parse("f64"), None);
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        // integration-ish: when `make artifacts` has run, the real
        // manifest must parse and reference existing files
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(dir).unwrap();
        assert!(m.payloads.len() >= 5);
        for p in &m.payloads {
            assert!(p.file.exists(), "{:?}", p.file);
            assert!(p.flops > 0);
        }
    }
}
