//! PJRT execution: HLO text → `HloModuleProto` → compile → execute.
//!
//! Follows the /opt/xla-example/load_hlo pattern: HLO *text* is the
//! interchange format (jax ≥ 0.5 emits 64-bit-id protos that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids), and the
//! AOT lowering used `return_tuple=True`, so every result unwraps a
//! 1-tuple.
//!
//! Executables compile once and are cached; the request path is
//! `execute()` only. Inputs are synthesized deterministically per
//! payload (seeded xoshiro), so runs are reproducible end-to-end.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{Context, Result};

use super::manifest::{Dtype, InputSpec, Manifest, PayloadMeta};
use crate::util::Xoshiro256;

/// Result of one payload execution.
#[derive(Clone, Debug)]
pub struct ExecReport {
    pub payload: String,
    /// wall-clock execution time (host), seconds
    pub wall_s: f64,
    /// analytic FLOPs of the payload (from the manifest)
    pub flops: u64,
    /// achieved FLOP/s on this host
    pub flops_per_sec: f64,
    /// checksum of the f32 output (sum of elements) for regression checks
    pub output_sum: f64,
    pub output_elems: usize,
}

/// The runtime: PJRT CPU client + executable cache.
pub struct PjRtRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl PjRtRuntime {
    /// Create a CPU-PJRT runtime over an artifact directory.
    pub fn load(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifact_dir)
            .with_context(|| format!("loading manifest from {:?}", artifact_dir.as_ref()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            cache: BTreeMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn payload_names(&self) -> Vec<&str> {
        self.manifest.payloads.iter().map(|p| p.name.as_str()).collect()
    }

    /// Compile (or fetch the cached executable for) a payload.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let meta = self
            .manifest
            .payload(name)
            .with_context(|| format!("unknown payload `{name}`"))?
            .clone();
        let path = meta
            .file
            .to_str()
            .context("artifact path not utf-8")?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling payload `{name}`"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }

    /// Synthesize a deterministic input literal for a spec.
    fn make_input(spec: &InputSpec, rng: &mut Xoshiro256) -> Result<xla::Literal> {
        let n = spec.element_count();
        let dims = spec.shape.clone();
        let lit = match spec.dtype {
            Dtype::F32 => {
                let data: Vec<f32> = (0..n)
                    .map(|_| rng.uniform_f64(-1.0, 1.0) as f32)
                    .collect();
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, n * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &dims,
                    bytes,
                )?
            }
            Dtype::I8 => {
                let data: Vec<i8> = (0..n)
                    .map(|_| rng.uniform_u64(0, 20) as i8 - 10)
                    .collect();
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, n)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S8,
                    &dims,
                    bytes,
                )?
            }
            Dtype::I32 => {
                let data: Vec<i32> = (0..n)
                    .map(|_| rng.uniform_u64(0, 100) as i32 - 50)
                    .collect();
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, n * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    &dims,
                    bytes,
                )?
            }
            Dtype::Bf16 => {
                // bf16 = upper 16 bits of the f32 pattern
                let data: Vec<u16> = (0..n)
                    .map(|_| {
                        let f = rng.uniform_f64(-1.0, 1.0) as f32;
                        (f.to_bits() >> 16) as u16
                    })
                    .collect();
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, n * 2)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::Bf16,
                    &dims,
                    bytes,
                )?
            }
        };
        Ok(lit)
    }

    /// Execute a payload once with seeded inputs; returns the report.
    pub fn execute(&mut self, name: &str, seed: u64) -> Result<ExecReport> {
        self.compile(name)?;
        let meta: PayloadMeta = self.manifest.payload(name).expect("compiled").clone();
        let mut rng = Xoshiro256::new(seed);
        let inputs: Vec<xla::Literal> = meta
            .inputs
            .iter()
            .map(|spec| Self::make_input(spec, &mut rng))
            .collect::<Result<_>>()?;
        let exe = self.cache.get(name).expect("compiled");
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let wall_s = t0.elapsed().as_secs_f64();
        // AOT lowered with return_tuple=True: unwrap the 1-tuple
        let out = result.to_tuple1()?;
        let (sum, elems) = summarize_output(&out)?;
        Ok(ExecReport {
            payload: name.to_string(),
            wall_s,
            flops: meta.flops,
            flops_per_sec: meta.flops as f64 / wall_s.max(1e-12),
            output_sum: sum,
            output_elems: elems,
        })
    }

    /// Execute `iters` times (after a warmup) and report the best run —
    /// standard microbenchmark practice for the perf pass.
    pub fn execute_best_of(&mut self, name: &str, seed: u64, iters: u32) -> Result<ExecReport> {
        let mut best: Option<ExecReport> = None;
        let _ = self.execute(name, seed)?; // warmup (first run pays compile)
        for i in 0..iters.max(1) {
            let r = self.execute(name, seed + i as u64)?;
            if best.as_ref().map(|b| r.wall_s < b.wall_s).unwrap_or(true) {
                best = Some(r);
            }
        }
        Ok(best.expect("at least one iteration"))
    }
}

/// Sum an output literal's elements for regression checksums.
fn summarize_output(lit: &xla::Literal) -> Result<(f64, usize)> {
    let elems = lit.element_count();
    let sum = match lit.ty()? {
        xla::ElementType::F32 => lit.to_vec::<f32>()?.iter().map(|v| *v as f64).sum(),
        xla::ElementType::S32 => lit.to_vec::<i32>()?.iter().map(|v| *v as f64).sum(),
        _ => f64::NAN,
    };
    Ok((sum, elems))
}

#[cfg(test)]
mod tests {
    //! These tests require `make artifacts` to have run; they skip
    //! (cleanly) when the artifact directory is absent so plain unit
    //! runs in a fresh checkout still pass. The integration tests in
    //! rust/tests/ hard-require the artifacts.
    use super::*;

    fn runtime() -> Option<PjRtRuntime> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            return None;
        }
        Some(PjRtRuntime::load(dir).expect("runtime"))
    }

    #[test]
    fn loads_and_compiles_gemm() {
        let Some(mut rt) = runtime() else { return };
        assert_eq!(rt.platform(), "cpu");
        rt.compile("gemm256").unwrap();
        assert_eq!(rt.compiled_count(), 1);
        rt.compile("gemm256").unwrap(); // cached, no recompile
        assert_eq!(rt.compiled_count(), 1);
    }

    #[test]
    fn executes_gemm_deterministically() {
        let Some(mut rt) = runtime() else { return };
        let a = rt.execute("gemm256", 7).unwrap();
        let b = rt.execute("gemm256", 7).unwrap();
        assert_eq!(a.output_sum, b.output_sum);
        assert_eq!(a.output_elems, 256 * 256);
        assert!(a.output_sum.is_finite());
        assert!(a.flops_per_sec > 0.0);
        // different seed -> different output
        let c = rt.execute("gemm256", 8).unwrap();
        assert_ne!(a.output_sum, c.output_sum);
    }

    #[test]
    fn executes_int8_dpa_payload() {
        let Some(mut rt) = runtime() else { return };
        let r = rt.execute("dpa4_gemm256", 3).unwrap();
        // int8 x int8 -> int32: sum is an exact integer
        assert_eq!(r.output_sum.fract(), 0.0);
        assert_eq!(r.output_elems, 256 * 256);
    }

    #[test]
    fn executes_cnn_payload() {
        let Some(mut rt) = runtime() else { return };
        let r = rt.execute("cnn_tiny", 1).unwrap();
        assert_eq!(r.output_elems, 10); // 1 x 10 logits
        assert!(r.output_sum.is_finite());
    }

    #[test]
    fn unknown_payload_errors() {
        let Some(mut rt) = runtime() else { return };
        assert!(rt.execute("not-a-payload", 0).is_err());
    }

    #[test]
    fn best_of_not_slower_than_single() {
        let Some(mut rt) = runtime() else { return };
        let single = rt.execute("gemm256", 1).unwrap();
        let best = rt.execute_best_of("gemm256", 1, 3).unwrap();
        assert!(best.wall_s <= single.wall_s * 1.5);
    }
}
