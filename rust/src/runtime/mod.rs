//! The PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) the python compile path produced once at build time,
//! compiles them on the PJRT CPU client, and executes them from the rust
//! request path. Python is never on this path.
//!
//! * [`manifest`] — typed view of `manifest.json`
//! * [`client`] — `PjRtRuntime`: compile-once executable cache + typed
//!   input synthesis + timed execution

pub mod client;
pub mod manifest;

pub use client::{ExecReport, PjRtRuntime};
pub use manifest::{Dtype, InputSpec, Manifest, PayloadMeta};
