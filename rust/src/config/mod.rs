//! Cluster configuration system.
//!
//! A real deployment of DALEK is described by a declarative config file
//! (the shipped [`ClusterConfig::dalek_default`] mirrors the paper's
//! exact topology). The format is a TOML subset parsed by [`toml_lite`]
//! — the full `toml`+`serde` crates are not vendored offline, and the
//! subset (tables, arrays of tables, strings, ints, floats, bools,
//! arrays) covers everything a cluster description needs.

pub mod cluster;
pub mod toml_lite;

pub use cluster::{ClusterConfig, PartitionConfig, PowerPolicyConfig, SchedulerConfig};
pub use toml_lite::{parse as parse_toml, TomlError, Value};
