//! A TOML-subset parser.
//!
//! Supported: `[table]` headers, `[[array-of-tables]]` headers, dotted
//! keys in headers, `key = value` with strings ("..."), integers,
//! floats, booleans, and homogeneous inline arrays `[a, b, c]`;
//! `#` comments. Unsupported (by design): dates, inline tables,
//! multi-line strings, key dots outside headers.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
    Table(BTreeMap<String, Value>),
    /// array of tables, from `[[name]]` headers
    TableArray(Vec<BTreeMap<String, Value>>),
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum TomlError {
    #[error("line {0}: {1}")]
    Syntax(usize, String),
    #[error("line {0}: duplicate key `{1}`")]
    DuplicateKey(usize, String),
    #[error("key `{0}`: expected {1}")]
    Type(String, &'static str),
    #[error("missing key `{0}`")]
    Missing(String),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_table(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }
    pub fn as_table_array(&self) -> Option<&[BTreeMap<String, Value>]> {
        match self {
            Value::TableArray(v) => Some(v),
            _ => None,
        }
    }

    /// Typed getters on tables, with path-aware errors.
    pub fn get<'a>(table: &'a BTreeMap<String, Value>, key: &str) -> Result<&'a Value, TomlError> {
        table.get(key).ok_or_else(|| TomlError::Missing(key.into()))
    }

    pub fn get_str(table: &BTreeMap<String, Value>, key: &str) -> Result<String, TomlError> {
        Self::get(table, key)?
            .as_str()
            .map(|s| s.to_string())
            .ok_or(TomlError::Type(key.into(), "string"))
    }

    pub fn get_int(table: &BTreeMap<String, Value>, key: &str) -> Result<i64, TomlError> {
        Self::get(table, key)?
            .as_int()
            .ok_or(TomlError::Type(key.into(), "integer"))
    }

    pub fn get_float(table: &BTreeMap<String, Value>, key: &str) -> Result<f64, TomlError> {
        Self::get(table, key)?
            .as_float()
            .ok_or(TomlError::Type(key.into(), "float"))
    }

    pub fn get_bool(table: &BTreeMap<String, Value>, key: &str) -> Result<bool, TomlError> {
        Self::get(table, key)?
            .as_bool()
            .ok_or(TomlError::Type(key.into(), "bool"))
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Table(_) | Value::TableArray(_) => write!(f, "<table>"),
        }
    }
}

/// Parse a document into its root table.
pub fn parse(src: &str) -> Result<BTreeMap<String, Value>, TomlError> {
    let mut root: BTreeMap<String, Value> = BTreeMap::new();
    // current insertion point expressed as a header path + array flag
    let mut path: Vec<String> = Vec::new();
    let mut in_array = false;

    for (ln, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        let lineno = ln + 1;
        if let Some(inner) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            path = split_header(inner, lineno)?;
            in_array = true;
            // append a fresh table to the array at `path`
            let arr = resolve_table_array(&mut root, &path, lineno)?;
            arr.push(BTreeMap::new());
        } else if let Some(inner) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            path = split_header(inner, lineno)?;
            in_array = false;
            resolve_table(&mut root, &path, lineno)?; // create
        } else if let Some((k, v)) = line.split_once('=') {
            let key = parse_key(k.trim(), lineno)?;
            let value = parse_value(v.trim(), lineno)?;
            let target = if in_array {
                resolve_table_array(&mut root, &path, lineno)?
                    .last_mut()
                    .expect("array has current element")
            } else {
                resolve_table(&mut root, &path, lineno)?
            };
            if target.contains_key(&key) {
                return Err(TomlError::DuplicateKey(lineno, key));
            }
            target.insert(key, value);
        } else {
            return Err(TomlError::Syntax(lineno, format!("cannot parse: {line}")));
        }
    }
    Ok(root)
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings; an escaped quote (`\"`) does
    // not close the string, so it cannot flip the scanner out of
    // string context and expose a later `#` for truncation
    let mut in_str = false;
    let mut escaped = false;
    for (i, ch) in line.char_indices() {
        if in_str {
            if escaped {
                escaped = false;
            } else if ch == '\\' {
                escaped = true;
            } else if ch == '"' {
                in_str = false;
            }
            continue;
        }
        match ch {
            '"' => in_str = true,
            '#' => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Scan a double-quoted string starting at the opening quote of `src`.
/// Returns the unescaped content and the byte length consumed (opening
/// through closing quote inclusive). `\n`, `\t`, `\"` and `\\` are
/// unescaped; unknown escapes stay literal.
fn scan_str(src: &str, lineno: usize) -> Result<(String, usize), TomlError> {
    debug_assert!(src.starts_with('"'));
    let mut out = String::new();
    let mut chars = src.char_indices().skip(1); // past the opening quote
    while let Some((i, ch)) = chars.next() {
        match ch {
            '"' => return Ok((out, i + 1)),
            '\\' => match chars.next() {
                Some((_, 'n')) => out.push('\n'),
                Some((_, 't')) => out.push('\t'),
                Some((_, '"')) => out.push('"'),
                Some((_, '\\')) => out.push('\\'),
                Some((_, other)) => {
                    out.push('\\');
                    out.push(other);
                }
                None => break,
            },
            c => out.push(c),
        }
    }
    Err(TomlError::Syntax(lineno, "unterminated string".into()))
}

fn split_header(inner: &str, lineno: usize) -> Result<Vec<String>, TomlError> {
    let parts: Vec<String> = inner.split('.').map(|p| p.trim().to_string()).collect();
    if parts.iter().any(|p| p.is_empty()) {
        return Err(TomlError::Syntax(lineno, format!("bad header [{inner}]")));
    }
    Ok(parts)
}

fn parse_key(k: &str, lineno: usize) -> Result<String, TomlError> {
    // quoted keys may contain anything a string may (incl. `#`)
    if k.starts_with('"') {
        let (s, used) = scan_str(k, lineno)?;
        if s.is_empty() || !k[used..].trim().is_empty() {
            return Err(TomlError::Syntax(lineno, format!("bad key `{k}`")));
        }
        return Ok(s);
    }
    if k.is_empty()
        || !k
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
    {
        return Err(TomlError::Syntax(lineno, format!("bad key `{k}`")));
    }
    Ok(k.to_string())
}

fn resolve_table<'a>(
    root: &'a mut BTreeMap<String, Value>,
    path: &[String],
    lineno: usize,
) -> Result<&'a mut BTreeMap<String, Value>, TomlError> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Value::Table(BTreeMap::new()));
        cur = match entry {
            Value::Table(t) => t,
            Value::TableArray(arr) => arr.last_mut().ok_or_else(|| {
                TomlError::Syntax(lineno, format!("empty table array `{part}`"))
            })?,
            _ => {
                return Err(TomlError::Syntax(
                    lineno,
                    format!("`{part}` is not a table"),
                ))
            }
        };
    }
    Ok(cur)
}

fn resolve_table_array<'a>(
    root: &'a mut BTreeMap<String, Value>,
    path: &[String],
    lineno: usize,
) -> Result<&'a mut Vec<BTreeMap<String, Value>>, TomlError> {
    let (last, prefix) = path.split_last().expect("non-empty header");
    let parent = resolve_table(root, prefix, lineno)?;
    let entry = parent
        .entry(last.clone())
        .or_insert_with(|| Value::TableArray(Vec::new()));
    match entry {
        Value::TableArray(arr) => Ok(arr),
        _ => Err(TomlError::Syntax(
            lineno,
            format!("`{last}` is not an array of tables"),
        )),
    }
}

fn parse_value(v: &str, lineno: usize) -> Result<Value, TomlError> {
    if v.is_empty() {
        return Err(TomlError::Syntax(lineno, "empty value".into()));
    }
    if v.starts_with('"') {
        let (s, used) = scan_str(v, lineno)?;
        if !v[used..].trim().is_empty() {
            return Err(TomlError::Syntax(
                lineno,
                format!("trailing characters after string: `{}`", &v[used..]),
            ));
        }
        return Ok(Value::Str(s));
    }
    if v == "true" {
        return Ok(Value::Bool(true));
    }
    if v == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = v.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let mut out = Vec::new();
        for item in split_array_items(inner) {
            out.push(parse_value(item.trim(), lineno)?);
        }
        return Ok(Value::Array(out));
    }
    let clean = v.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(TomlError::Syntax(lineno, format!("cannot parse value `{v}`")))
}

/// Split a flat array body on commas (strings may contain commas).
fn split_array_items(s: &str) -> Vec<&str> {
    let mut items = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut escaped = false;
    let mut start = 0usize;
    for (i, ch) in s.char_indices() {
        if in_str {
            if escaped {
                escaped = false;
            } else if ch == '\\' {
                escaped = true;
            } else if ch == '"' {
                in_str = false;
            }
            continue;
        }
        match ch {
            '"' => in_str = true,
            '[' => depth += 1,
            ']' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                items.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    items.push(&s[start..]);
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        let doc = parse(
            r#"
name = "dalek"
nodes = 16
rate = 2.5
wol = true
"#,
        )
        .unwrap();
        assert_eq!(Value::get_str(&doc, "name").unwrap(), "dalek");
        assert_eq!(Value::get_int(&doc, "nodes").unwrap(), 16);
        assert_eq!(Value::get_float(&doc, "rate").unwrap(), 2.5);
        assert!(Value::get_bool(&doc, "wol").unwrap());
    }

    #[test]
    fn int_coerces_to_float() {
        let doc = parse("x = 3\n").unwrap();
        assert_eq!(Value::get_float(&doc, "x").unwrap(), 3.0);
    }

    #[test]
    fn tables_and_nesting() {
        let doc = parse(
            r#"
[scheduler]
policy = "backfill"
[scheduler.power]
suspend_after_mins = 10
"#,
        )
        .unwrap();
        let sched = doc["scheduler"].as_table().unwrap();
        assert_eq!(Value::get_str(sched, "policy").unwrap(), "backfill");
        let power = sched["power"].as_table().unwrap();
        assert_eq!(Value::get_int(power, "suspend_after_mins").unwrap(), 10);
    }

    #[test]
    fn array_of_tables() {
        let doc = parse(
            r#"
[[partition]]
name = "az4-n4090"
nodes = 4
[[partition]]
name = "az5-a890m"
nodes = 4
"#,
        )
        .unwrap();
        let parts = doc["partition"].as_table_array().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(Value::get_str(&parts[0], "name").unwrap(), "az4-n4090");
        assert_eq!(Value::get_str(&parts[1], "name").unwrap(), "az5-a890m");
    }

    #[test]
    fn arrays_and_comments() {
        let doc = parse(
            r#"
# header comment
sizes = [1, 2, 3]   # inline comment
names = ["a", "b#c"]
empty = []
"#,
        )
        .unwrap();
        assert_eq!(
            doc["sizes"].as_array().unwrap(),
            &[Value::Int(1), Value::Int(2), Value::Int(3)]
        );
        assert_eq!(
            doc["names"].as_array().unwrap()[1],
            Value::Str("b#c".into())
        );
        assert!(doc["empty"].as_array().unwrap().is_empty());
    }

    #[test]
    fn underscore_separators_in_numbers() {
        let doc = parse("big = 2_500_000_000\n").unwrap();
        assert_eq!(Value::get_int(&doc, "big").unwrap(), 2_500_000_000);
    }

    #[test]
    fn duplicate_key_rejected() {
        let e = parse("a = 1\na = 2\n").unwrap_err();
        assert!(matches!(e, TomlError::DuplicateKey(2, k) if k == "a"));
    }

    #[test]
    fn bad_syntax_reports_line() {
        let e = parse("ok = 1\nnot a kv\n").unwrap_err();
        assert!(matches!(e, TomlError::Syntax(2, _)));
    }

    #[test]
    fn missing_and_wrong_type_errors() {
        let doc = parse("x = 1\n").unwrap();
        assert_eq!(
            Value::get_str(&doc, "y").unwrap_err(),
            TomlError::Missing("y".into())
        );
        assert_eq!(
            Value::get_str(&doc, "x").unwrap_err(),
            TomlError::Type("x".into(), "string")
        );
    }

    #[test]
    fn keys_under_table_array_element() {
        let doc = parse(
            r#"
[[p]]
name = "one"
[p.extra]
flag = true
"#,
        )
        .unwrap();
        let parts = doc["p"].as_table_array().unwrap();
        let extra = parts[0]["extra"].as_table().unwrap();
        assert!(Value::get_bool(extra, "flag").unwrap());
    }

    #[test]
    fn unterminated_string_rejected() {
        assert!(matches!(parse("s = \"oops\n"), Err(TomlError::Syntax(1, _))));
    }

    #[test]
    fn hash_after_escaped_quote_is_not_a_comment() {
        // pre-fix, the comment scanner toggled string context on the
        // escaped quote and truncated the line at `#`
        let doc = parse("a = \"x \\\" # y\"\n").unwrap();
        assert_eq!(Value::get_str(&doc, "a").unwrap(), "x \" # y");
    }

    #[test]
    fn comment_after_value_with_escaped_quote() {
        // pre-fix this truncated mid-string and mis-reported the line
        // as Syntax("unterminated string")
        let doc = parse("a = \"x \\\" y\" # z\n").unwrap();
        assert_eq!(Value::get_str(&doc, "a").unwrap(), "x \" y");
    }

    #[test]
    fn quoted_key_may_contain_hash() {
        let doc = parse("\"a#b\" = 1\n").unwrap();
        assert_eq!(Value::get_int(&doc, "a#b").unwrap(), 1);
    }

    #[test]
    fn array_items_with_escaped_quotes_and_hash() {
        let doc = parse("xs = [\"p \\\" q\", \"r#s\"] # tail\n").unwrap();
        assert_eq!(
            doc["xs"].as_array().unwrap(),
            &[Value::Str("p \" q".into()), Value::Str("r#s".into())]
        );
    }

    #[test]
    fn standard_escapes_unescape() {
        let doc = parse("a = \"l1\\nl2\\tend\\\\\"\n").unwrap();
        assert_eq!(Value::get_str(&doc, "a").unwrap(), "l1\nl2\tend\\");
    }

    #[test]
    fn trailing_garbage_after_string_rejected() {
        assert!(matches!(
            parse("a = \"x\" y\n"),
            Err(TomlError::Syntax(1, _))
        ));
    }
}
