//! Typed cluster configuration, loadable from a TOML-subset file and
//! shipped with a default that reproduces the paper's exact deployment.
//!
//! The config controls what a site operator would actually tune: which
//! partitions exist (hardware models come from the `hw` catalog by
//! name), the scheduler policy, the §3.4 power policy (suspend timeout,
//! boot budget), network numbering (Listing 1) and the energy-platform
//! probe layout (§4).

use std::collections::BTreeMap;

use super::toml_lite::{parse, TomlError, Value};
use crate::hw::catalog::{
    partition_az4_a7900, partition_az4_n4090, partition_az5_a890m, partition_iml_ia770,
    PartitionSpec,
};
use crate::sim::SimTime;

/// One partition entry.
#[derive(Clone, Debug, PartialEq)]
pub struct PartitionConfig {
    /// must name a catalog partition (az4-n4090, az4-a7900, …)
    pub name: String,
    pub nodes: u32,
    /// third octet block index for Listing 1 subnetting
    pub subnet_index: u8,
}

/// §3.4 node-powering strategy.
#[derive(Clone, Debug, PartialEq)]
pub struct PowerPolicyConfig {
    /// power off after this idle duration (paper: 10 minutes)
    pub suspend_after: SimTime,
    /// resume budget (paper: "up to a 2-minute delay")
    pub max_boot_delay: SimTime,
    /// whether the §3.4 WoL strategy is enabled at all
    pub enabled: bool,
}

impl Default for PowerPolicyConfig {
    fn default() -> Self {
        Self {
            suspend_after: SimTime::from_mins(10),
            max_boot_delay: SimTime::from_mins(2),
            enabled: true,
        }
    }
}

/// Scheduler policy knobs.
#[derive(Clone, Debug, PartialEq)]
pub struct SchedulerConfig {
    /// "fifo" or "backfill"
    pub policy: String,
    /// scheduling tick
    pub tick: SimTime,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            policy: "backfill".into(),
            tick: SimTime::from_secs(1),
        }
    }
}

/// Energy measurement platform layout (§4).
#[derive(Clone, Debug, PartialEq)]
pub struct EnergyConfig {
    /// probes per main board I2C connector chain (max 6, paper §4.1)
    pub probes_per_node: u32,
    /// requested per-probe sample rate (paper: 1000 SPS averaged)
    pub sample_rate_sps: u32,
}

impl Default for EnergyConfig {
    fn default() -> Self {
        Self {
            probes_per_node: 1,
            sample_rate_sps: 1000,
        }
    }
}

/// The full cluster description.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    pub name: String,
    /// base /24 network (paper: 192.168.1.0/24)
    pub network_base: [u8; 3],
    pub partitions: Vec<PartitionConfig>,
    pub power: PowerPolicyConfig,
    pub scheduler: SchedulerConfig,
    pub energy: EnergyConfig,
    pub seed: u64,
}

impl ClusterConfig {
    /// The deployment of the paper: 4 partitions × 4 nodes, §3.4 power
    /// policy, one USB-C probe per node at 1000 SPS.
    pub fn dalek_default() -> Self {
        Self {
            name: "dalek".into(),
            network_base: [192, 168, 1],
            partitions: vec![
                PartitionConfig {
                    name: "az4-n4090".into(),
                    nodes: 4,
                    subnet_index: 0,
                },
                PartitionConfig {
                    name: "az4-a7900".into(),
                    nodes: 4,
                    subnet_index: 1,
                },
                PartitionConfig {
                    name: "iml-ia770".into(),
                    nodes: 4,
                    subnet_index: 2,
                },
                PartitionConfig {
                    name: "az5-a890m".into(),
                    nodes: 4,
                    subnet_index: 3,
                },
            ],
            power: PowerPolicyConfig::default(),
            scheduler: SchedulerConfig::default(),
            energy: EnergyConfig::default(),
            seed: 0xDA1EC,
        }
    }

    /// A fleet-scale deployment: the paper's four catalog partitions
    /// scaled out to `total_nodes` compute nodes (remainder nodes go to
    /// the leading partitions). Addressing past each rack's /27 block
    /// comes from the fleet extension ranges in
    /// [`SubnetPlan::node_ip`](crate::net::addr::SubnetPlan::node_ip).
    pub fn fleet(total_nodes: u32) -> Self {
        assert!(total_nodes >= 4, "a fleet has at least one node per partition");
        let mut cfg = Self::dalek_default();
        cfg.name = format!("dalek-fleet-{total_nodes}");
        let per = total_nodes / 4;
        let extra = (total_nodes % 4) as usize;
        for (i, p) in cfg.partitions.iter_mut().enumerate() {
            p.nodes = per + u32::from(i < extra);
        }
        cfg
    }

    /// Parse from the TOML-subset format. Missing sections fall back to
    /// the paper's defaults; unknown partition names are rejected here
    /// (they could not be resolved against the hw catalog later).
    pub fn from_toml(src: &str) -> Result<Self, TomlError> {
        let doc = parse(src)?;
        let mut cfg = Self::dalek_default();
        if let Some(v) = doc.get("name").and_then(Value::as_str) {
            cfg.name = v.to_string();
        }
        if let Some(v) = doc.get("seed").and_then(Value::as_int) {
            cfg.seed = v as u64;
        }
        if let Some(arr) = doc.get("partition").and_then(Value::as_table_array) {
            cfg.partitions.clear();
            for (i, t) in arr.iter().enumerate() {
                let name = Value::get_str(t, "name")?;
                resolve_partition(&name).ok_or_else(|| {
                    TomlError::Type("partition.name".into(), "a known catalog partition")
                })?;
                cfg.partitions.push(PartitionConfig {
                    name,
                    nodes: Value::get_int(t, "nodes").unwrap_or(4) as u32,
                    subnet_index: t
                        .get("subnet_index")
                        .and_then(Value::as_int)
                        .unwrap_or(i as i64) as u8,
                });
            }
        }
        if let Some(t) = doc.get("power").and_then(Value::as_table) {
            apply_power(&mut cfg.power, t)?;
        }
        if let Some(t) = doc.get("scheduler").and_then(Value::as_table) {
            if let Some(p) = t.get("policy").and_then(Value::as_str) {
                if p != "fifo" && p != "backfill" {
                    return Err(TomlError::Type("scheduler.policy".into(), "fifo|backfill"));
                }
                cfg.scheduler.policy = p.to_string();
            }
            if let Some(s) = t.get("tick_secs").and_then(Value::as_int) {
                cfg.scheduler.tick = SimTime::from_secs(s as u64);
            }
        }
        if let Some(t) = doc.get("energy").and_then(Value::as_table) {
            if let Some(n) = t.get("probes_per_node").and_then(Value::as_int) {
                if !(1..=12).contains(&n) {
                    return Err(TomlError::Type(
                        "energy.probes_per_node".into(),
                        "1..=12 (two I2C chains of six, §4.1)",
                    ));
                }
                cfg.energy.probes_per_node = n as u32;
            }
            if let Some(r) = t.get("sample_rate_sps").and_then(Value::as_int) {
                cfg.energy.sample_rate_sps = r as u32;
            }
        }
        Ok(cfg)
    }

    /// Total compute nodes across partitions.
    pub fn total_nodes(&self) -> u32 {
        self.partitions.iter().map(|p| p.nodes).sum()
    }
}

fn apply_power(
    p: &mut PowerPolicyConfig,
    t: &BTreeMap<String, Value>,
) -> Result<(), TomlError> {
    if let Some(m) = t.get("suspend_after_mins").and_then(Value::as_int) {
        p.suspend_after = SimTime::from_mins(m as u64);
    }
    if let Some(m) = t.get("max_boot_delay_mins").and_then(Value::as_int) {
        p.max_boot_delay = SimTime::from_mins(m as u64);
    }
    if let Some(b) = t.get("enabled").and_then(Value::as_bool) {
        p.enabled = b;
    }
    Ok(())
}

/// Resolve a partition name against the hardware catalog.
pub fn resolve_partition(name: &str) -> Option<PartitionSpec> {
    match name {
        "az4-n4090" => Some(partition_az4_n4090()),
        "az4-a7900" => Some(partition_az4_a7900()),
        "iml-ia770" => Some(partition_iml_ia770()),
        "az5-a890m" => Some(partition_az5_a890m()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper() {
        let c = ClusterConfig::dalek_default();
        assert_eq!(c.total_nodes(), 16);
        assert_eq!(c.partitions.len(), 4);
        assert_eq!(c.power.suspend_after, SimTime::from_mins(10));
        assert_eq!(c.power.max_boot_delay, SimTime::from_mins(2));
        assert_eq!(c.network_base, [192, 168, 1]);
    }

    #[test]
    fn fleet_scales_partitions_evenly() {
        let c = ClusterConfig::fleet(10_000);
        assert_eq!(c.total_nodes(), 10_000);
        assert!(c.partitions.iter().all(|p| p.nodes == 2_500));
        let c = ClusterConfig::fleet(10);
        assert_eq!(c.total_nodes(), 10);
        assert_eq!(
            c.partitions.iter().map(|p| p.nodes).collect::<Vec<_>>(),
            vec![3, 3, 2, 2]
        );
        // rack-sized fleet is the paper deployment with another name
        let mut c = ClusterConfig::fleet(16);
        c.name = "dalek".into();
        assert_eq!(c, ClusterConfig::dalek_default());
    }

    #[test]
    fn toml_round_trip_overrides() {
        let cfg = ClusterConfig::from_toml(
            r#"
name = "dalek-test"
seed = 7

[[partition]]
name = "az5-a890m"
nodes = 2

[power]
suspend_after_mins = 5
enabled = false

[scheduler]
policy = "fifo"
tick_secs = 2

[energy]
probes_per_node = 6
sample_rate_sps = 500
"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "dalek-test");
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.partitions.len(), 1);
        assert_eq!(cfg.partitions[0].nodes, 2);
        assert_eq!(cfg.power.suspend_after, SimTime::from_mins(5));
        assert!(!cfg.power.enabled);
        assert_eq!(cfg.scheduler.policy, "fifo");
        assert_eq!(cfg.scheduler.tick, SimTime::from_secs(2));
        assert_eq!(cfg.energy.probes_per_node, 6);
        assert_eq!(cfg.energy.sample_rate_sps, 500);
    }

    #[test]
    fn empty_toml_is_paper_default() {
        assert_eq!(
            ClusterConfig::from_toml("").unwrap(),
            ClusterConfig::dalek_default()
        );
    }

    #[test]
    fn unknown_partition_rejected() {
        let e = ClusterConfig::from_toml("[[partition]]\nname = \"bogus\"\n").unwrap_err();
        assert!(matches!(e, TomlError::Type(_, _)));
    }

    #[test]
    fn bad_scheduler_policy_rejected() {
        let e = ClusterConfig::from_toml("[scheduler]\npolicy = \"lottery\"\n").unwrap_err();
        assert!(matches!(e, TomlError::Type(_, _)));
    }

    #[test]
    fn probe_count_bounds_enforced() {
        // 13 probes exceed the two six-probe I2C chains of §4.1
        let e = ClusterConfig::from_toml("[energy]\nprobes_per_node = 13\n").unwrap_err();
        assert!(matches!(e, TomlError::Type(_, _)));
    }

    #[test]
    fn subnet_index_defaults_to_position() {
        let cfg = ClusterConfig::from_toml(
            "[[partition]]\nname = \"az4-n4090\"\n[[partition]]\nname = \"iml-ia770\"\n",
        )
        .unwrap();
        assert_eq!(cfg.partitions[0].subnet_index, 0);
        assert_eq!(cfg.partitions[1].subnet_index, 1);
    }

    #[test]
    fn shipped_config_file_matches_default() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/dalek.toml");
        let src = std::fs::read_to_string(path).expect("configs/dalek.toml");
        assert_eq!(
            ClusterConfig::from_toml(&src).unwrap(),
            ClusterConfig::dalek_default()
        );
    }

    #[test]
    fn resolve_partition_names() {
        for n in ["az4-n4090", "az4-a7900", "iml-ia770", "az5-a890m"] {
            assert!(resolve_partition(n).is_some(), "{n}");
        }
        assert!(resolve_partition("nope").is_none());
    }
}
