//! `ClusterApi` — the single authenticated entry point to the cluster.
//!
//! Composes, per the paper, the SLURM controller with the §3.4 power
//! policy, one §4 main board per compute node (probes sampling the
//! scheduler's ground-truth power signal), the LDAP directory, and
//! optionally the PJRT runtime — and fronts all of it with the session
//! + protocol layer of this module:
//!
//! * a user logs in once ([`ClusterApi::login`]) and every subsequent
//!   operation presents the [`SessionId`] capability;
//! * every operation is reachable both as a typed method and as a
//!   JSON [`Request`] through [`ClusterApi::handle`] /
//!   [`ClusterApi::handle_json`];
//! * `EnergyApi` and `SlurmApi` are crate-internal routing targets —
//!   nothing outside `dalek::api` constructs them or threads raw
//!   `(db, login)` credentials.
//!
//! The simulation-driver surface (`run_until`, `report`, `submit` as
//! the operator console) stays on this type too, routed through a
//! built-in root session, so trace replay and the benches drive the
//! same stack users do.

use std::collections::BTreeMap;

use super::error::DalekError;
use super::protocol::{JobRequest, JobView, Request, Response};
use super::session::{Session, SessionId, SessionManager};
use crate::config::ClusterConfig;
use crate::energy::api::PowerAction;
use crate::energy::{EnergyApi, MainBoard, ProbeConfig, Sample};
use crate::power::Activity;
use crate::runtime::{ExecReport, PjRtRuntime};
use crate::services::auth::UserDb;
use crate::sim::SimTime;
use crate::slurm::{JobId, JobSpec, JobState, Slurm, SlurmApi};
use crate::util::Xoshiro256;

/// Cluster-level summary for reports.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub now: SimTime,
    pub jobs_completed: u64,
    pub jobs_pending: usize,
    pub cluster_watts: f64,
    pub true_energy_j: f64,
    /// energy integrated from probe samples (should track true_energy)
    pub measured_energy_j: f64,
    pub samples: u64,
}

/// Assumed sustained fraction of a node's roofline for payload jobs.
/// GEMM-class kernels on consumer CPUs sustain roughly a quarter of
/// peak FMA throughput; documented in DESIGN.md §Perf.
const CPU_EFFICIENCY: f64 = 0.25;
const GPU_EFFICIENCY: f64 = 0.30;

/// The shared cluster credential key (MUNGE `/etc/munge/munge.key`).
const MUNGE_KEY: &[u8] = b"dalek-cluster-munge-key";

/// Sliding session lifetime (renewed on every validated request).
const SESSION_TTL: SimTime = SimTime(7 * 24 * 3600 * 1_000_000_000);

/// How far one non-admin `run_job` may drive the shared sim clock.
/// `srun` blocks until the job terminates, which in a discrete-event
/// cluster means advancing time for everyone — the same capability the
/// `advance` op restricts to admins. Jobs are therefore clamped to a
/// 24 h time limit per non-admin call (longer jobs hit `Timeout`).
const NON_ADMIN_SRUN_HORIZON: SimTime = SimTime(24 * 3600 * 1_000_000_000);

pub struct ClusterApi {
    pub cfg: ClusterConfig,
    slurm: SlurmApi,
    energy: EnergyApi,
    users: UserDb,
    sessions: SessionManager,
    runtime: Option<PjRtRuntime>,
    rng: Xoshiro256,
    /// nodes with probes attached (board key = node name)
    node_names: Vec<String>,
    sampled_to: SimTime,
    /// the operator-console session (root), auto-renewed
    root: SessionId,
}

impl ClusterApi {
    /// Build the full cluster; `artifact_dir = None` runs without the
    /// PJRT runtime (synthetic workloads only).
    pub fn new(cfg: ClusterConfig, artifact_dir: Option<&str>) -> anyhow::Result<Self> {
        let ctl = Slurm::from_config(&cfg);
        let mut rng = Xoshiro256::new(cfg.seed);
        let mut energy = EnergyApi::new();
        let mut node_names = Vec::new();
        let probe_cfg = ProbeConfig {
            adc_sps: cfg.energy.sample_rate_sps * 4,
            ..ProbeConfig::default()
        };
        for pc in &cfg.partitions {
            for n in 0..pc.nodes {
                let name = format!("{}-{}", pc.name, n);
                let mut board = MainBoard::new(name.clone());
                for probe in 0..cfg.energy.probes_per_node {
                    board
                        .attach_probe(
                            probe as u8,
                            probe_cfg.clone(),
                            rng.fork(&format!("{name}/p{probe}")),
                            4096,
                        )
                        .expect("config bounds probes to 12");
                }
                energy.add_board(board);
                node_names.push(name);
            }
        }
        let mut users = UserDb::new();
        users.add_user("root", true).expect("fresh db");
        // token-derivation key = cluster key ‖ config seed, so tokens
        // differ per cluster instance. The sim necessarily hardcodes
        // the MUNGE key in source (a real deployment loads a secret
        // /etc/munge/munge.key); per-instance mixing is the honest
        // equivalent of that secrecy the simulation can offer while
        // staying deterministic for replay.
        let mut token_key = MUNGE_KEY.to_vec();
        token_key.extend_from_slice(&cfg.seed.to_le_bytes());
        let mut sessions = SessionManager::new(&token_key, SESSION_TTL);
        let root = sessions
            .login(&users, "root", SimTime::ZERO)
            .expect("root just created")
            .id;
        let runtime = match artifact_dir {
            Some(dir) => Some(PjRtRuntime::load(dir)?),
            None => None,
        };
        Ok(Self {
            cfg,
            slurm: SlurmApi::new(ctl, MUNGE_KEY),
            energy,
            users,
            sessions,
            runtime,
            rng,
            node_names,
            sampled_to: SimTime::ZERO,
            root,
        })
    }

    // -----------------------------------------------------------------
    // sessions
    // -----------------------------------------------------------------

    /// Authenticate and open a session at the current cluster time.
    pub fn login(&mut self, user: &str) -> Result<SessionId, DalekError> {
        let now = self.now();
        Ok(self.sessions.login(&self.users, user, now)?.id)
    }

    /// Close a session; returns whether it existed.
    pub fn logout(&mut self, id: SessionId) -> bool {
        self.sessions.logout(id)
    }

    fn session(&mut self, id: SessionId, now: SimTime) -> Result<Session, DalekError> {
        self.sessions.validate(id, now)
    }

    fn admin_session(&mut self, id: SessionId, now: SimTime) -> Result<Session, DalekError> {
        let s = self.session(id, now)?;
        if !s.admin {
            return Err(DalekError::AdminOnly);
        }
        Ok(s)
    }

    /// The operator-console session, re-opened if it ever expired.
    fn root_session(&mut self, now: SimTime) -> Session {
        if let Ok(s) = self.sessions.validate(self.root, now) {
            return s;
        }
        let sess = self
            .sessions
            .login(&self.users, "root", now)
            .expect("root always exists");
        self.root = sess.id;
        sess
    }

    // -----------------------------------------------------------------
    // directory (operator provisioning, outside the wire protocol —
    // the protocol path is `Request::AddUser`, admin-gated)
    // -----------------------------------------------------------------

    /// Ensure a (non-admin) account exists; idempotent.
    pub fn add_user(&mut self, login: &str) {
        let _ = self.users.add_user(login, false);
    }

    /// Admin-gated account creation (the `add_user` protocol op).
    pub fn add_user_as(
        &mut self,
        sid: SessionId,
        login: &str,
        admin: bool,
    ) -> Result<(), DalekError> {
        let now = self.now();
        self.admin_session(sid, now)?;
        self.users.add_user(login, admin)?;
        Ok(())
    }

    // -----------------------------------------------------------------
    // accessors
    // -----------------------------------------------------------------

    pub fn now(&self) -> SimTime {
        self.slurm.ctl.now()
    }

    /// Read-only view of the controller (reports, node tables, tests).
    pub fn slurm(&self) -> &Slurm {
        &self.slurm.ctl
    }

    pub fn has_runtime(&self) -> bool {
        self.runtime.is_some()
    }

    pub fn runtime(&self) -> Option<&PjRtRuntime> {
        self.runtime.as_ref()
    }

    /// Deterministic sub-RNG for workload generators.
    pub fn fork_rng(&mut self, label: &str) -> Xoshiro256 {
        self.rng.fork(label)
    }

    // -----------------------------------------------------------------
    // job control (sessions)
    // -----------------------------------------------------------------

    fn owner_for(&self, sess: &Session, requested: &Option<String>) -> Result<String, DalekError> {
        match requested {
            Some(u) if *u != sess.login => {
                if !sess.admin {
                    return Err(DalekError::AdminOnly);
                }
                self.users.user(u)?; // must exist
                Ok(u.clone())
            }
            _ => Ok(sess.login.clone()),
        }
    }

    fn spec_from_request(
        &mut self,
        owner: &str,
        req: &JobRequest,
    ) -> Result<JobSpec, DalekError> {
        if req.nodes == 0 {
            return Err(DalekError::BadRequest("`nodes` must be at least 1".into()));
        }
        match &req.payload {
            Some(payload) => {
                // duration comes from the payload grounding, but an
                // explicit client time limit is still honored
                let mut spec =
                    self.payload_spec(owner, &req.partition, req.nodes, payload, req.iters)?;
                if let Some(tl) = req.time_limit {
                    spec.time_limit = tl;
                }
                Ok(spec)
            }
            None => Ok(JobSpec {
                user: owner.into(),
                partition: req.partition.clone(),
                nodes: req.nodes,
                duration: req.duration,
                time_limit: req.time_limit.unwrap_or(SimTime(
                    req.duration
                        .as_ns()
                        .saturating_mul(4)
                        .saturating_add(60_000_000_000),
                )),
                payload: None,
                activity: Activity::cpu_only(0.95),
            }),
        }
    }

    /// Build a payload-backed spec: execute the AOT artifact once for
    /// real (grounding + checksum), then size `iters` iterations on the
    /// target partition's roofline.
    fn payload_spec(
        &mut self,
        owner: &str,
        partition: &str,
        nodes: u32,
        payload: &str,
        iters: u64,
    ) -> Result<JobSpec, DalekError> {
        let rt = self.runtime.as_mut().ok_or(DalekError::NoRuntime)?;
        let report = rt
            .execute(payload, self.cfg.seed ^ iters)
            .map_err(|e| DalekError::Runtime(format!("{e:#}")))?;
        if !report.output_sum.is_finite() {
            return Err(DalekError::Runtime(format!(
                "payload `{payload}` produced non-finite output"
            )));
        }
        let spec_part = crate::config::cluster::resolve_partition(partition).ok_or_else(|| {
            DalekError::Slurm(crate::slurm::scheduler::SlurmError::UnknownPartition(
                partition.into(),
            ))
        })?;
        // GPU-heavy payloads run on the dGPU where one exists
        let on_gpu = spec_part.node.dgpu.is_some()
            && (payload.starts_with("gemm") || payload.starts_with("cnn"));
        let (roofline, eff, activity) = if on_gpu {
            (
                spec_part.node.dgpu.as_ref().expect("checked").peak_f32(),
                GPU_EFFICIENCY,
                Activity {
                    cpu: 0.3,
                    dgpu: 0.95,
                    igpu: 0.0,
                },
            )
        } else {
            (
                spec_part
                    .node
                    .cpu
                    .peak_ops_accumulated(crate::hw::cpu::Instr::FmaF32),
                CPU_EFFICIENCY,
                Activity::cpu_only(0.95),
            )
        };
        let total_flops = report.flops as f64 * iters as f64;
        let per_node = total_flops / nodes as f64;
        let secs = per_node / (roofline * eff);
        let duration = SimTime::from_secs_f64(secs.max(1e-3));
        Ok(JobSpec {
            user: owner.into(),
            partition: partition.into(),
            nodes,
            duration,
            time_limit: duration + SimTime::from_mins(10),
            payload: Some(payload.into()),
            activity,
        })
    }

    /// sbatch for an already-validated session (single validation per
    /// request; the MUNGE per-RPC round-trip still happens in sbatch).
    fn submit_as(
        &mut self,
        sess: &Session,
        spec: JobSpec,
        now: SimTime,
    ) -> Result<JobId, DalekError> {
        if spec.user != sess.login && !sess.admin {
            return Err(DalekError::AdminOnly);
        }
        self.users.user(&spec.user)?; // owner must exist
        Ok(self.slurm.sbatch(sess.uid, spec, now)?)
    }

    fn request_as(
        &mut self,
        sess: &Session,
        req: &JobRequest,
        now: SimTime,
    ) -> Result<JobId, DalekError> {
        let owner = self.owner_for(sess, &req.user)?;
        let spec = self.spec_from_request(&owner, req)?;
        Ok(self.slurm.sbatch(sess.uid, spec, now)?)
    }

    /// sbatch through a session: queue and return the job id. The spec's
    /// owner must be the session user unless the session is an admin's.
    pub fn submit_spec(
        &mut self,
        sid: SessionId,
        spec: JobSpec,
        now: SimTime,
    ) -> Result<JobId, DalekError> {
        let sess = self.session(sid, now)?;
        self.submit_as(&sess, spec, now)
    }

    /// The `submit_job` protocol op.
    pub fn submit_request(
        &mut self,
        sid: SessionId,
        req: &JobRequest,
        now: SimTime,
    ) -> Result<JobId, DalekError> {
        let sess = self.session(sid, now)?;
        self.request_as(&sess, req, now)
    }

    /// The `run_job` protocol op (srun): submit and block — drive the
    /// simulation — until the job reaches a terminal state.
    pub fn run_request(
        &mut self,
        sid: SessionId,
        req: &JobRequest,
        now: SimTime,
    ) -> Result<(JobId, JobState), DalekError> {
        let sess = self.session(sid, now)?;
        let owner = self.owner_for(&sess, &req.user)?;
        let mut spec = self.spec_from_request(&owner, req)?;
        // srun drives the shared sim clock; bound both the job's own
        // runtime and the total advance (queue wait included) for
        // non-admins — the unbounded version is the admin `advance` op
        let deadline = if sess.admin {
            None
        } else {
            spec.time_limit = spec.time_limit.min(NON_ADMIN_SRUN_HORIZON);
            Some(now.max(self.now()) + NON_ADMIN_SRUN_HORIZON)
        };
        match self.slurm.srun(sess.uid, spec, now, deadline) {
            Ok(r) => Ok(r),
            // deadline hit: don't leave an unreferencable orphan queued
            // under the user's name (a job already Running holds real
            // resources and finishes within the clamped limit)
            Err(crate::slurm::api::ApiError::Deadline(id)) => {
                let _ = self.slurm.ctl.cancel(id);
                Err(DalekError::Deadline(id))
            }
            Err(e) => Err(e.into()),
        }
    }

    /// The `alloc_nodes` protocol op (salloc): reserve nodes and open
    /// the SSH gate; returns the allocated node names.
    pub fn alloc_request(
        &mut self,
        sid: SessionId,
        req: &JobRequest,
        now: SimTime,
    ) -> Result<(JobId, Vec<String>), DalekError> {
        let sess = self.session(sid, now)?;
        let owner = self.owner_for(&sess, &req.user)?;
        let spec = self.spec_from_request(&owner, req)?;
        let id = self.slurm.salloc(sess.uid, spec, now)?;
        let job = self.slurm.ctl.job(id).expect("just submitted");
        // salloc returns Ok even when the boot budget elapsed with the
        // job still queued — that is a failed allocation on this
        // surface. A job that already ran to termination during the
        // wait loop DID hold its allocation, so only never-allocated
        // states are failures.
        if matches!(job.state, JobState::Pending | JobState::Cancelled) {
            let _ = self.slurm.ctl.cancel(id); // don't leave it queued
            return Err(DalekError::Incomplete);
        }
        let infos = self.slurm.ctl.node_infos();
        let nodes = job
            .allocated
            .iter()
            .map(|&i| infos[i].name.clone())
            .collect();
        Ok((id, nodes))
    }

    /// squeue-style job lookup (any authenticated user).
    pub fn job_info(&mut self, sid: SessionId, id: JobId) -> Result<JobView, DalekError> {
        let now = self.now();
        self.session(sid, now)?;
        let job = self.slurm.ctl.job(id).ok_or(DalekError::UnknownJob(id))?;
        Ok(JobView {
            job: job.id,
            user: job.spec.user.clone(),
            partition: job.spec.partition.clone(),
            state: job.state,
            nodes: job.spec.nodes,
            submitted: job.submitted,
            started: job.started,
            finished: job.finished,
        })
    }

    /// scancel: the owner or an admin may cancel.
    pub fn cancel(&mut self, sid: SessionId, id: JobId) -> Result<(), DalekError> {
        let now = self.now();
        let sess = self.session(sid, now)?;
        let owner = self
            .slurm
            .ctl
            .job(id)
            .ok_or(DalekError::UnknownJob(id))?
            .spec
            .user
            .clone();
        if owner != sess.login && !sess.admin {
            return Err(DalekError::AdminOnly);
        }
        Ok(self.slurm.ctl.cancel(id)?)
    }

    // -----------------------------------------------------------------
    // energy platform (§4.3, sessions)
    // -----------------------------------------------------------------

    /// Retrieve measured samples — all users. `decimate = n` keeps every
    /// n-th sample; returns `(total_in_window, kept)`.
    pub fn samples(
        &mut self,
        sid: SessionId,
        node: &str,
        probe: u8,
        window: (SimTime, SimTime),
        decimate: u32,
    ) -> Result<(u64, Vec<Sample>), DalekError> {
        let now = self.now();
        self.session(sid, now)?;
        let all = self.energy.samples(node, probe, window)?;
        let total = all.len() as u64;
        let step = decimate.max(1) as usize;
        Ok((total, all.into_iter().step_by(step).collect()))
    }

    /// Tag samples via the GPIO inputs — all users.
    pub fn set_tag(
        &mut self,
        sid: SessionId,
        node: &str,
        line: u8,
        high: bool,
    ) -> Result<(), DalekError> {
        let now = self.now();
        self.session(sid, now)?;
        Ok(self.energy.set_gpio_tag(node, line, high)?)
    }

    /// Manual node power control — administrators only.
    pub fn power(&mut self, sid: SessionId, node: &str, on: bool) -> Result<(), DalekError> {
        let now = self.now();
        self.admin_session(sid, now)?;
        self.energy.board(node)?; // must name a real board
        let action = if on {
            PowerAction::On(node.into())
        } else {
            PowerAction::Off(node.into())
        };
        self.energy.queue_power(action);
        Ok(())
    }

    /// Measured energy: whole cluster, one node, or one node windowed.
    pub fn query_energy(
        &mut self,
        sid: SessionId,
        node: Option<&str>,
        window: Option<(SimTime, SimTime)>,
    ) -> Result<f64, DalekError> {
        let now = self.now();
        self.session(sid, now)?;
        let nprobes = self.cfg.energy.probes_per_node as u8;
        let windowed = |board: &MainBoard, (a, b)| -> Result<f64, DalekError> {
            let mut j = 0.0;
            for p in 0..nprobes {
                j += board.store(p)?.window_energy_j(a, b);
            }
            Ok(j)
        };
        match (node, window) {
            (None, None) => Ok(self.energy.total_energy_j()),
            (None, Some(w)) => {
                let mut j = 0.0;
                for board in self.energy.boards() {
                    j += windowed(board, w)?;
                }
                Ok(j)
            }
            (Some(n), None) => Ok(self.energy.board(n)?.total_energy_j()),
            (Some(n), Some(w)) => windowed(self.energy.board(n)?, w),
        }
    }

    // -----------------------------------------------------------------
    // runtime (sessions)
    // -----------------------------------------------------------------

    /// Execute an AOT payload on the PJRT runtime (best of `iters`).
    pub fn exec_payload(
        &mut self,
        sid: SessionId,
        payload: &str,
        seed: u64,
        iters: u32,
    ) -> Result<ExecReport, DalekError> {
        let now = self.now();
        self.session(sid, now)?;
        let rt = self.runtime.as_mut().ok_or(DalekError::NoRuntime)?;
        rt.execute_best_of(payload, seed, iters.max(1))
            .map_err(|e| DalekError::Runtime(format!("{e:#}")))
    }

    // -----------------------------------------------------------------
    // operator console — the same stack, driven through the built-in
    // root session (trace replay, benches, the CLI `run` command)
    // -----------------------------------------------------------------

    /// Submit a synthetic job as the operator, on behalf of `spec.user`
    /// (the account is provisioned if missing — site-admin style).
    pub fn submit(&mut self, spec: JobSpec, now: SimTime) -> Result<JobId, DalekError> {
        self.add_user(&spec.user);
        let root = self.root_session(now);
        self.submit_as(&root, spec, now)
    }

    /// Submit a payload-backed job as the operator: executes the AOT
    /// artifact once for real, then simulates `iters` iterations on the
    /// target partition's hardware.
    pub fn submit_payload(
        &mut self,
        user: &str,
        partition: &str,
        nodes: u32,
        payload: &str,
        iters: u64,
        now: SimTime,
    ) -> Result<JobId, DalekError> {
        self.add_user(user);
        let root = self.root_session(now);
        let req = JobRequest {
            partition: partition.into(),
            nodes,
            duration: SimTime::ZERO, // sized from the payload grounding
            time_limit: None,
            payload: Some(payload.into()),
            iters,
            user: Some(user.into()),
        };
        self.request_as(&root, &req, now)
    }

    /// Advance the whole cluster to `t`. When `sample` is set, the §4
    /// boards sample every node's (piecewise-constant) power signal at
    /// the configured rate, replayed exactly from the scheduler's power
    /// history — sampling therefore never misses energy, regardless of
    /// how the scheduler clock advanced (submissions, run_until calls).
    pub fn run_until(&mut self, t: SimTime, sample: bool) {
        self.slurm.ctl.run_until(t);
        if !sample {
            return;
        }
        let from = self.sampled_to;
        if t <= from {
            return; // never resample a covered window
        }
        for name in &self.node_names {
            let hist = self.slurm.ctl.node_history(name).expect("known node");
            let board = match self.energy.board_mut(name) {
                Ok(b) => b,
                Err(_) => continue,
            };
            let nprobes = self.cfg.energy.probes_per_node as u8;
            // walk the change points covering (from, t]
            for (i, &(start, w)) in hist.iter().enumerate() {
                let seg_end = hist.get(i + 1).map(|(s, _)| *s).unwrap_or(t).min(t);
                if seg_end <= from || start >= t {
                    continue;
                }
                let sigs: BTreeMap<u8, _> =
                    (0..nprobes).map(|p| (p, move |_t: SimTime| w)).collect();
                board.poll(seg_end, &sigs);
            }
        }
        // §4.3 admin power actions queued via the energy API
        for action in self.energy.drain_actions() {
            let _ = action; // manual power control is reported, not forced
        }
        self.sampled_to = t;
        self.slurm.ctl.gc_history(t);
    }

    /// Current summary.
    pub fn report(&self) -> ClusterReport {
        let samples = self
            .energy
            .boards()
            .map(|b| {
                (0..self.cfg.energy.probes_per_node as u8)
                    .filter_map(|p| b.store(p).ok())
                    .map(|s| s.total_samples())
                    .sum::<u64>()
            })
            .sum();
        ClusterReport {
            now: self.slurm.ctl.now(),
            jobs_completed: self.slurm.ctl.stats.completed,
            jobs_pending: self.slurm.ctl.pending_count(),
            cluster_watts: self.slurm.ctl.cluster_watts(),
            true_energy_j: self.slurm.ctl.total_energy_j(),
            measured_energy_j: self.energy.total_energy_j(),
            samples,
        }
    }

    // -----------------------------------------------------------------
    // the protocol dispatcher
    // -----------------------------------------------------------------

    /// Execute one typed request. `Login` needs no session; everything
    /// else requires a valid token.
    pub fn handle(
        &mut self,
        sid: Option<SessionId>,
        req: &Request,
    ) -> Result<Response, DalekError> {
        let now = self.now();
        if let Request::Login { user } = req {
            let sess = self.sessions.login(&self.users, user, now)?;
            return Ok(Response::Session {
                id: sess.id,
                user: sess.login,
                admin: sess.admin,
            });
        }
        let sid = sid.ok_or(DalekError::InvalidSession)?;
        match req {
            Request::Login { .. } => unreachable!("handled above"),
            Request::Logout => {
                if self.logout(sid) {
                    Ok(Response::LoggedOut)
                } else {
                    Err(DalekError::InvalidSession)
                }
            }
            Request::AddUser { user, admin } => {
                self.add_user_as(sid, user, *admin)?;
                Ok(Response::UserAdded { user: user.clone() })
            }
            Request::SubmitJob(r) => {
                let job = self.submit_request(sid, r, now)?;
                Ok(Response::Submitted { job })
            }
            Request::RunJob(r) => {
                let (job, state) = self.run_request(sid, r, now)?;
                Ok(Response::JobRan { job, state })
            }
            Request::AllocNodes(r) => {
                let (job, nodes) = self.alloc_request(sid, r, now)?;
                Ok(Response::Allocated { job, nodes })
            }
            Request::JobInfo { job } => Ok(Response::Job(self.job_info(sid, *job)?)),
            Request::CancelJob { job } => {
                self.cancel(sid, *job)?;
                Ok(Response::Cancelled { job: *job })
            }
            Request::QuerySamples {
                node,
                probe,
                from,
                to,
                decimate,
            } => {
                let (total, samples) =
                    self.samples(sid, node, *probe, (*from, *to), *decimate)?;
                Ok(Response::Samples {
                    node: node.clone(),
                    probe: *probe,
                    total,
                    samples,
                })
            }
            Request::QueryEnergy { node, window } => {
                let joules = self.query_energy(sid, node.as_deref(), *window)?;
                Ok(Response::Energy { joules })
            }
            Request::SetTag { node, line, high } => {
                self.set_tag(sid, node, *line, *high)?;
                Ok(Response::TagSet {
                    node: node.clone(),
                    line: *line,
                    high: *high,
                })
            }
            Request::Power { node, on } => {
                self.power(sid, node, *on)?;
                Ok(Response::PowerQueued {
                    node: node.clone(),
                    on: *on,
                })
            }
            Request::ClusterReport => {
                self.session(sid, now)?;
                let r = self.report();
                Ok(Response::Report {
                    now: r.now,
                    jobs_completed: r.jobs_completed,
                    jobs_pending: r.jobs_pending,
                    cluster_watts: r.cluster_watts,
                    true_energy_j: r.true_energy_j,
                    measured_energy_j: r.measured_energy_j,
                    samples: r.samples,
                })
            }
            Request::Advance { to, sample } => {
                self.admin_session(sid, now)?;
                self.run_until(*to, *sample);
                Ok(Response::Advanced { now: self.now() })
            }
            Request::ExecPayload {
                payload,
                iters,
                seed,
            } => {
                let r = self.exec_payload(sid, payload, *seed, *iters)?;
                Ok(Response::Executed {
                    payload: r.payload,
                    wall_s: r.wall_s,
                    flops: r.flops,
                    flops_per_sec: r.flops_per_sec,
                    output_sum: r.output_sum,
                })
            }
        }
    }

    /// Execute one JSON envelope and encode the reply — the scriptable
    /// wire surface (`dalek api request.json`). Never panics on bad
    /// input: malformed requests and execution failures both come back
    /// as `{"ok": false, "error": ...}`.
    pub fn handle_json(&mut self, src: &str) -> String {
        let resp = match Request::parse(src) {
            Ok((sid, req)) => match self.handle(sid, &req) {
                Ok(r) => r,
                Err(e) => Response::from_error(&e),
            },
            Err(e) => Response::from_error(&e),
        };
        resp.to_json().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slurm::JobState;

    fn cluster() -> ClusterApi {
        ClusterApi::new(ClusterConfig::dalek_default(), None).unwrap()
    }

    fn artifacts_dir() -> Option<&'static str> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        std::path::Path::new(dir)
            .join("manifest.json")
            .exists()
            .then_some(dir)
    }

    #[test]
    fn builds_16_boards() {
        let c = cluster();
        assert_eq!(c.energy.boards().count(), 16);
        assert_eq!(c.node_names.len(), 16);
    }

    #[test]
    fn measured_energy_tracks_truth() {
        let mut c = cluster();
        c.submit(JobSpec::cpu("root", "az5-a890m", 2, 120), SimTime::ZERO)
            .unwrap();
        c.run_until(SimTime::from_mins(8), true);
        let r = c.report();
        assert!(r.samples > 0);
        assert!(r.true_energy_j > 0.0);
        // probes quantize to mW and add noise; agreement within 1%
        let rel = (r.measured_energy_j - r.true_energy_j).abs() / r.true_energy_j;
        assert!(rel < 0.01, "rel error {rel}: {r:?}");
    }

    #[test]
    fn sampling_rate_is_configured_1000_sps() {
        let mut c = cluster();
        c.run_until(SimTime::from_secs(10), true);
        let r = c.report();
        // 16 nodes x 1 probe x 1000 SPS x 10 s
        let expect = 16.0 * 1000.0 * 10.0;
        let got = r.samples as f64;
        assert!((got - expect).abs() / expect < 0.01, "{got} vs {expect}");
    }

    #[test]
    fn unsampled_run_is_cheap_and_equivalent_in_truth() {
        let mut a = cluster();
        let mut b = cluster();
        a.submit(JobSpec::cpu("root", "az4-n4090", 4, 300), SimTime::ZERO)
            .unwrap();
        b.submit(JobSpec::cpu("root", "az4-n4090", 4, 300), SimTime::ZERO)
            .unwrap();
        a.run_until(SimTime::from_mins(30), false);
        b.run_until(SimTime::from_mins(30), true);
        let (ra, rb) = (a.report(), b.report());
        assert_eq!(ra.jobs_completed, rb.jobs_completed);
        assert!((ra.true_energy_j - rb.true_energy_j).abs() < 1e-6);
        assert_eq!(ra.samples, 0);
    }

    #[test]
    fn payload_job_runs_real_artifact_then_simulates() {
        let Some(dir) = artifacts_dir() else { return };
        let mut c = ClusterApi::new(ClusterConfig::dalek_default(), Some(dir)).unwrap();
        c.add_user("alice");
        let id = c
            .submit_payload("alice", "az4-n4090", 2, "gemm256", 50_000, SimTime::ZERO)
            .unwrap();
        c.run_until(SimTime::from_hours(2), false);
        let job = c.slurm().job(id).unwrap();
        assert_eq!(job.state, JobState::Completed, "{:?}", job.state);
        assert_eq!(job.spec.payload.as_deref(), Some("gemm256"));
        // GPU-backed duration: 50k x 33.5 MFLOP / 2 nodes on 4090s
        // (≈0.84 TFLOP/node over a ~25 TFLOP/s effective roofline)
        let d = job.spec.duration.as_secs_f64();
        assert!(d > 0.01 && d < 600.0, "duration {d}");
        // sanity: the same payload on the CPU-only partition is slower
        let id2 = c
            .submit_payload("alice", "az5-a890m", 2, "gemm256", 50_000, c.now())
            .unwrap();
        c.run_until(c.now() + SimTime::from_hours(4), false);
        let d2 = c.slurm().job(id2).unwrap().spec.duration.as_secs_f64();
        assert!(d2 > 5.0 * d, "CPU {d2} vs GPU {d}");
    }

    #[test]
    fn payload_requires_runtime() {
        let mut c = cluster();
        assert!(matches!(
            c.submit_payload("root", "az4-n4090", 1, "gemm256", 1, SimTime::ZERO),
            Err(DalekError::NoRuntime)
        ));
    }

    // ---- session semantics over the composed stack ----

    #[test]
    fn login_session_submit_flow() {
        let mut c = cluster();
        c.add_user("alice");
        let sid = c.login("alice").unwrap();
        let req = JobRequest {
            partition: "az5-a890m".into(),
            nodes: 1,
            duration: SimTime::from_secs(60),
            time_limit: None,
            payload: None,
            iters: 1,
            user: None,
        };
        let id = c.submit_request(sid, &req, SimTime::ZERO).unwrap();
        c.run_until(SimTime::from_mins(10), false);
        let v = c.job_info(sid, id).unwrap();
        assert_eq!(v.user, "alice");
        assert_eq!(v.state, JobState::Completed);
    }

    #[test]
    fn unknown_user_cannot_login() {
        let mut c = cluster();
        assert!(matches!(c.login("mallory"), Err(DalekError::Auth(_))));
    }

    #[test]
    fn non_admin_cannot_submit_on_behalf_nor_power() {
        let mut c = cluster();
        c.add_user("alice");
        c.add_user("bob");
        let sid = c.login("alice").unwrap();
        let mut req = JobRequest {
            partition: "az5-a890m".into(),
            nodes: 1,
            duration: SimTime::from_secs(30),
            time_limit: None,
            payload: None,
            iters: 1,
            user: Some("bob".into()),
        };
        assert!(matches!(
            c.submit_request(sid, &req, SimTime::ZERO),
            Err(DalekError::AdminOnly)
        ));
        req.user = None;
        assert!(c.submit_request(sid, &req, SimTime::ZERO).is_ok());
        assert!(matches!(
            c.power(sid, "az5-a890m-0", false),
            Err(DalekError::AdminOnly)
        ));
    }

    #[test]
    fn admin_powers_and_advances() {
        let mut c = cluster();
        let sid = c.login("root").unwrap();
        c.power(sid, "az5-a890m-0", false).unwrap();
        assert!(matches!(
            c.power(sid, "no-such-node", true),
            Err(DalekError::NoBoard(_))
        ));
        let r = c
            .handle(
                Some(sid),
                &Request::Advance {
                    to: SimTime::from_secs(30),
                    sample: true,
                },
            )
            .unwrap();
        assert!(matches!(r, Response::Advanced { now } if now >= SimTime::from_secs(30)));
    }

    #[test]
    fn samples_and_energy_through_session() {
        let mut c = cluster();
        c.submit(JobSpec::cpu("root", "az5-a890m", 2, 120), SimTime::ZERO)
            .unwrap();
        c.run_until(SimTime::from_secs(30), true);
        let sid = c.login("root").unwrap();
        let (total, kept) = c
            .samples(
                sid,
                "az5-a890m-0",
                0,
                (SimTime::ZERO, SimTime::from_secs(30)),
                10,
            )
            .unwrap();
        assert!(total > 0);
        assert!(kept.len() <= total as usize / 10 + 1);
        let j = c.query_energy(sid, None, None).unwrap();
        assert!(j > 0.0);
        let jn = c
            .query_energy(sid, Some("az5-a890m-0"), None)
            .unwrap();
        assert!(jn > 0.0 && jn <= j);
    }

    #[test]
    fn cancel_requires_owner_or_admin() {
        let mut c = cluster();
        c.add_user("alice");
        c.add_user("eve");
        let alice = c.login("alice").unwrap();
        let eve = c.login("eve").unwrap();
        let blocker = JobRequest {
            partition: "az4-n4090".into(),
            nodes: 4,
            duration: SimTime::from_secs(3600),
            time_limit: None,
            payload: None,
            iters: 1,
            user: None,
        };
        c.submit_request(alice, &blocker, SimTime::ZERO).unwrap();
        // the partition is fully reserved, so this one stays Pending
        let req = JobRequest {
            nodes: 1,
            duration: SimTime::from_secs(600),
            ..blocker
        };
        let id = c.submit_request(alice, &req, SimTime::ZERO).unwrap();
        assert_eq!(c.job_info(alice, id).unwrap().state, JobState::Pending);
        assert!(matches!(
            c.cancel(eve, id),
            Err(DalekError::AdminOnly)
        ));
        c.cancel(alice, id).unwrap();
        assert_eq!(c.job_info(alice, id).unwrap().state, JobState::Cancelled);
    }

    #[test]
    fn logout_revokes_capability() {
        let mut c = cluster();
        let sid = c.login("root").unwrap();
        assert!(c.logout(sid));
        assert!(matches!(
            c.handle(Some(sid), &Request::ClusterReport),
            Err(DalekError::InvalidSession)
        ));
        assert!(matches!(
            c.handle(None, &Request::ClusterReport),
            Err(DalekError::InvalidSession)
        ));
    }
}
