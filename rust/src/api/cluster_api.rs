//! `ClusterApi` — the single authenticated entry point to the cluster.
//!
//! Composes, per the paper, the SLURM controller with the §3.4 power
//! policy, one §4 main board per compute node (probes sampling the
//! scheduler's ground-truth power signal), the LDAP directory, the
//! frontend services and the flow network, and optionally the PJRT
//! runtime — and fronts all of it with the session + protocol layer of
//! this module:
//!
//! * a user logs in once ([`ClusterApi::login`]) and every subsequent
//!   operation presents the [`SessionId`] capability;
//! * every operation is reachable both as a typed method and as a
//!   JSON [`Request`] through [`ClusterApi::handle`] /
//!   [`ClusterApi::handle_json`];
//! * `EnergyApi` and `SlurmApi` are crate-internal routing targets —
//!   nothing outside `dalek::api` constructs them or threads raw
//!   `(db, login)` credentials.
//!
//! ## The unified kernel
//!
//! All time advancement happens on one [`sim::Kernel`](crate::sim::Kernel) owned here. The
//! routing enum [`ClusterEvent`] carries every subsystem's events —
//! scheduler boot/shutdown/suspend/job timers, network flow
//! completions, service ticks — and [`ClusterApi::run_until`] is the
//! only dispatch loop. Energy sampling is no longer a post-hoc history
//! replay: the scheduler publishes `PowerTransition`s and the
//! [`StreamingSampler`] emits each constant-power segment's samples in
//! one closed-form batch, so `run_until(t, sample = true)` costs time
//! proportional to the number of power *changes*, not simulated
//! seconds. Queued §4.3 admin power actions are applied to the node
//! FSMs through `Slurm::admin_power` at the next tick (they used to be
//! discarded).

use std::collections::BTreeMap;

use super::error::DalekError;
use super::events::{Channel, Event, JobEventKind, Outbox, PowerEventKind, Ticket};
use super::protocol::{JobRequest, JobView, Request, Response};
use super::session::{Session, SessionId, SessionManager};
use crate::app::{AppEngine, AppEvent};
use crate::config::cluster::resolve_partition;
use crate::config::ClusterConfig;
use crate::energy::api::PowerAction;
use crate::energy::sampler::ROLLING_HORIZON;
use crate::energy::{EnergyApi, MainBoard, ProbeConfig, Sample, StreamingSampler};
use crate::faults::{FaultKind, FaultPlan, FaultSpec};
use crate::net::{FlowId, FlowNet, HostId, NetEvent, Topology};
use crate::power::Activity;
use crate::query::standing::StandingQuery;
use crate::query::{ClusterTree, Expr as QueryExpr, QueryOutput, QueryValue, WindowSpec};
use crate::runtime::{ExecReport, PjRtRuntime};
use crate::services::auth::UserDb;
use crate::services::{ServiceEvent, ServiceRack};
use crate::sim::{Kernel, SimTime};
use crate::slurm::{
    JobId, JobLifecycle, JobSpec, JobState, NodeFault, PlacementPolicy, PolicyEvent,
    PowerGovernor, SchedEvent, Slurm, SlurmApi,
};
use crate::util::Xoshiro256;

/// The cluster's kernel routing enum: every subsystem's events on the
/// one event list, dispatched by [`ClusterApi::run_until`].
#[derive(Clone, Copy, Debug)]
pub enum ClusterEvent {
    Sched(SchedEvent),
    Service(ServiceEvent),
    Net(NetEvent),
    Policy(PolicyEvent),
    /// `dalek::app` BSP barrier timers (compute-phase rank completions)
    App(AppEvent),
    /// `dalek::faults` plan edges (injection / recovery instants)
    Fault(FaultEvent),
}

/// A fault-plan edge riding the kernel: the index addresses the armed
/// entry in [`ClusterApi`]'s installed plan.
#[derive(Clone, Copy, Debug)]
pub enum FaultEvent {
    Inject(usize),
    Recover(usize),
}

impl From<SchedEvent> for ClusterEvent {
    fn from(e: SchedEvent) -> Self {
        ClusterEvent::Sched(e)
    }
}
impl From<ServiceEvent> for ClusterEvent {
    fn from(e: ServiceEvent) -> Self {
        ClusterEvent::Service(e)
    }
}
impl From<NetEvent> for ClusterEvent {
    fn from(e: NetEvent) -> Self {
        ClusterEvent::Net(e)
    }
}
impl From<PolicyEvent> for ClusterEvent {
    fn from(e: PolicyEvent) -> Self {
        ClusterEvent::Policy(e)
    }
}
impl From<AppEvent> for ClusterEvent {
    fn from(e: AppEvent) -> Self {
        ClusterEvent::App(e)
    }
}
impl From<FaultEvent> for ClusterEvent {
    fn from(e: FaultEvent) -> Self {
        ClusterEvent::Fault(e)
    }
}

/// Governor telemetry + actuation snapshot (the `power_report` op).
#[derive(Clone, Debug)]
pub struct PowerReport {
    pub budget_w: Option<f64>,
    /// measured rolling-window cluster draw, watts
    pub rolling_w: f64,
    pub window_s: f64,
    /// instantaneous true cluster draw, watts
    pub cluster_w: f64,
    /// throttle factor at the last control tick (1.0 = uncapped)
    pub throttle: f64,
    pub capped_nodes: u32,
    pub governor_ticks: u64,
    pub idle_shutdowns: u64,
}

/// Cluster-level summary for reports.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub now: SimTime,
    pub jobs_completed: u64,
    pub jobs_pending: usize,
    pub cluster_watts: f64,
    pub true_energy_j: f64,
    /// energy integrated from probe samples (should track true_energy)
    pub measured_energy_j: f64,
    pub samples: u64,
}

/// Assumed sustained fraction of a node's roofline for payload jobs.
/// GEMM-class kernels on consumer CPUs sustain roughly a quarter of
/// peak FMA throughput; documented in DESIGN.md §Perf.
const CPU_EFFICIENCY: f64 = 0.25;
const GPU_EFFICIENCY: f64 = 0.30;

/// The shared cluster credential key (MUNGE `/etc/munge/munge.key`).
const MUNGE_KEY: &[u8] = b"dalek-cluster-munge-key";

/// Sliding session lifetime (renewed on every validated request).
const SESSION_TTL: SimTime = SimTime(7 * 24 * 3600 * 1_000_000_000);

/// How far one non-admin `run_job` may drive the shared sim clock.
/// `srun` blocks until the job terminates, which in a discrete-event
/// cluster means advancing time for everyone — the same capability the
/// `advance` op restricts to admins. Jobs are therefore clamped to a
/// 24 h time limit per non-admin call (longer jobs hit `Timeout`).
const NON_ADMIN_SRUN_HORIZON: SimTime = SimTime(24 * 3600 * 1_000_000_000);

/// srun advances the simulation in strides this long between job-state
/// checks (the blocking-command poll granularity).
const SRUN_STRIDE: SimTime = SimTime(10 * 60 * 1_000_000_000);

/// Default bound on a session's event outbox. A slow consumer loses
/// the oldest events and is told so ([`Event::Lagged`]) instead of
/// growing the server without bound.
const OUTBOX_CAP: usize = 256;

/// How far event time may run inside one `drive` before the event
/// plane is pumped mid-drain. Telemetry windows are cut from the
/// 120 s rolling history, so pumps must happen at least twice per
/// horizon; events fire at least every 64 s (the NTP discipline
/// re-arms unconditionally), so pacing at half the horizon keeps every
/// cursor comfortably inside it even across hour-long `run_until`s.
const EVENT_PUMP_INTERVAL: SimTime = SimTime(60 * 1_000_000_000);

/// One session's live subscription state + bounded outbox.
struct SessionSubs {
    /// owner scoping for `JobEvents` (admins see every job)
    user: String,
    admin: bool,
    job_events: bool,
    power_events: bool,
    /// `dalek::faults` injection/recovery edges (admin-gated channel)
    fault_events: bool,
    /// decimated telemetry cursor: `(period, start of the next window)`
    telemetry: Option<(SimTime, SimTime)>,
    /// registered standing DQL queries (the `query_events` channel)
    standing: Vec<StandingQuery>,
    outbox: Outbox,
}

impl SessionSubs {
    fn new(user: String, admin: bool, cap: usize) -> Self {
        Self {
            user,
            admin,
            job_events: false,
            power_events: false,
            fault_events: false,
            telemetry: None,
            standing: Vec::new(),
            outbox: Outbox::new(cap),
        }
    }
}

/// One installed fault, resolved against the live cluster at arm time
/// so the injection/recovery handlers never re-run name lookup (and a
/// link recovery restores the exact pre-fault capacity).
struct ArmedFault {
    spec: FaultSpec,
    /// scheduler node index (node-plane faults)
    node_idx: Option<usize>,
    /// `(host, nominal NIC bps at arm time)` (link-plane faults)
    link: Option<(HostId, f64)>,
    /// did the inject edge actually take effect? An ad-hoc fault may
    /// already hold the node when this entry's inject edge fires; the
    /// matching recover edge must then not clear a fault it never
    /// placed (it would cut the other fault's outage short).
    fired: bool,
}

pub struct ClusterApi {
    pub cfg: ClusterConfig,
    /// the single clock + event list every subsystem registers with
    kernel: Kernel<ClusterEvent>,
    slurm: SlurmApi,
    energy: EnergyApi,
    sampler: StreamingSampler,
    /// §3.6 power-cap governor; its periodic tick rides the kernel as
    /// [`PolicyEvent::GovernorTick`] while a budget is set
    governor: PowerGovernor,
    services: ServiceRack,
    topo: Topology,
    net: FlowNet,
    /// executes `dalek::app` programs: compute barriers on the kernel,
    /// collective phases lowered onto the flow network
    apps: AppEngine,
    users: UserDb,
    sessions: SessionManager,
    runtime: Option<PjRtRuntime>,
    rng: Xoshiro256,
    /// the operator-console session (root), auto-renewed
    root: SessionId,
    /// per-session subscriptions + bounded event outboxes (BTreeMap:
    /// deterministic fan-out order)
    subs: BTreeMap<SessionId, SessionSubs>,
    /// live `salloc` allocations held per session — released (not
    /// leaked) when the session logs out or expires
    session_allocs: BTreeMap<SessionId, Vec<JobId>>,
    /// monotonic receipt counter for nonblocking submissions
    next_ticket: u64,
    /// governor-plane events staged by `on_governor_tick` until the
    /// next `pump_events`
    pending_power: Vec<(SimTime, PowerEventKind)>,
    /// the armed `dalek::faults` plan entries, addressed by the
    /// [`FaultEvent`] indices riding the kernel
    fault_plan: Vec<ArmedFault>,
    /// link-plane fault edges (which never pass through the scheduler,
    /// so produce no `FaultNotice`) staged for the next `pump_events`:
    /// `(at, host name, kind, injected)`
    pending_faults: Vec<(SimTime, String, FaultKind, bool)>,
    /// outbox bound applied to new subscriptions (tests shrink it to
    /// force overflow, telemetry-heavy runs raise it)
    outbox_cap: usize,
}

impl ClusterApi {
    /// Build the full cluster; `artifact_dir = None` runs without the
    /// PJRT runtime (synthetic workloads only).
    pub fn new(cfg: ClusterConfig, artifact_dir: Option<&str>) -> anyhow::Result<Self> {
        let ctl = Slurm::from_config(&cfg);
        let mut rng = Xoshiro256::new(cfg.seed);
        let mut energy = EnergyApi::new();
        let mut sampler = StreamingSampler::new();
        let probe_cfg = ProbeConfig {
            adc_sps: cfg.energy.sample_rate_sps * 4,
            ..ProbeConfig::default()
        };
        for pc in &cfg.partitions {
            let spec = resolve_partition(&pc.name).expect("validated config");
            for n in 0..pc.nodes {
                let name = format!("{}-{}", pc.name, n);
                let mut board = MainBoard::new(name.clone());
                // nodes start suspended; the stream needs the same
                // initial truth the scheduler integrates from
                let stream = sampler.add_node(name.clone(), spec.node.power.suspend_w);
                for probe in 0..cfg.energy.probes_per_node {
                    let probe_rng = rng.fork(&format!("{name}/p{probe}"));
                    board
                        .attach_probe(probe as u8, probe_cfg.clone(), probe_rng.clone(), 4096)
                        .expect("config bounds probes to 12");
                    stream.add_probe(&probe_cfg, probe_rng);
                }
                energy.add_board(board);
            }
        }
        let mut users = UserDb::new();
        users.add_user("root", true).expect("fresh db");
        // token-derivation key = cluster key ‖ config seed, so tokens
        // differ per cluster instance. The sim necessarily hardcodes
        // the MUNGE key in source (a real deployment loads a secret
        // /etc/munge/munge.key); per-instance mixing is the honest
        // equivalent of that secrecy the simulation can offer while
        // staying deterministic for replay.
        let mut token_key = MUNGE_KEY.to_vec();
        token_key.extend_from_slice(&cfg.seed.to_le_bytes());
        let mut sessions = SessionManager::new(&token_key, SESSION_TTL);
        let root = sessions
            .login(&users, "root", SimTime::ZERO)
            .expect("root just created")
            .id;
        let runtime = match artifact_dir {
            Some(dir) => Some(PjRtRuntime::load(dir)?),
            None => None,
        };
        let mut services = ServiceRack::new(&cfg, &mut rng);
        let topo = Topology::build(&cfg);
        let net = FlowNet::new(&topo);
        let mut kernel = Kernel::new();
        services.start(&mut kernel);
        Ok(Self {
            cfg,
            kernel,
            slurm: SlurmApi::new(ctl, MUNGE_KEY),
            energy,
            sampler,
            governor: PowerGovernor::new(),
            services,
            topo,
            net,
            apps: AppEngine::new(),
            users,
            sessions,
            runtime,
            rng,
            root,
            subs: BTreeMap::new(),
            session_allocs: BTreeMap::new(),
            next_ticket: 1,
            pending_power: Vec::new(),
            fault_plan: Vec::new(),
            pending_faults: Vec::new(),
            outbox_cap: OUTBOX_CAP,
        })
    }

    // -----------------------------------------------------------------
    // sessions
    // -----------------------------------------------------------------

    /// Authenticate and open a session at the current cluster time.
    pub fn login(&mut self, user: &str) -> Result<SessionId, DalekError> {
        let now = self.now();
        Ok(self.sessions.login(&self.users, user, now)?.id)
    }

    /// Close a session; returns whether it existed. Teardown is
    /// complete: subscriptions are dropped and any live `salloc`
    /// allocation the session holds is released (nodes freed, SSH
    /// grants revoked) — an interactive session must not leak its
    /// reservation past its own lifetime.
    pub fn logout(&mut self, id: SessionId) -> bool {
        let existed = self.sessions.logout(id);
        self.teardown_session(id);
        existed
    }

    fn session(&mut self, id: SessionId, now: SimTime) -> Result<Session, DalekError> {
        match self.sessions.validate(id, now) {
            Ok(s) => Ok(s),
            Err(e) => {
                // expired (or forged) token: the same teardown as an
                // explicit logout, so expiry cannot leak an allocation
                self.teardown_session(id);
                Err(e)
            }
        }
    }

    /// Drop a session's subscriptions and release its live `salloc`
    /// allocations. Idempotent; harmless for unknown sessions.
    fn teardown_session(&mut self, sid: SessionId) {
        self.subs.remove(&sid);
        let jobs = self.session_allocs.remove(&sid).unwrap_or_default();
        if jobs.is_empty() {
            return;
        }
        let now = self.now();
        for id in jobs {
            let info = self.slurm.ctl.job(id).and_then(|job| {
                (!job.is_terminal()).then(|| (job.spec.user.clone(), job.allocated.clone()))
            });
            let Some((user, alloc)) = info else { continue };
            let nodes: Vec<String> = alloc
                .iter()
                .map(|&i| self.slurm.ctl.node_name(i).to_string())
                .collect();
            // a phase-structured program must not fire after its nodes
            // are gone: tear down the engine run (barrier timer +
            // in-flight collective flows) before releasing
            self.apps.cancel(&mut self.net, &mut self.kernel, id);
            let _ = self.slurm.ctl.release_job(&mut self.kernel, id, now);
            for n in &nodes {
                self.slurm.gate.revoke(n, &user);
            }
        }
        // other subscribers still learn the jobs finished
        self.pump_events();
    }

    fn admin_session(&mut self, id: SessionId, now: SimTime) -> Result<Session, DalekError> {
        let s = self.session(id, now)?;
        if !s.admin {
            return Err(DalekError::AdminOnly);
        }
        Ok(s)
    }

    /// The operator-console session, re-opened if it ever expired.
    fn root_session(&mut self, now: SimTime) -> Session {
        if let Ok(s) = self.sessions.validate(self.root, now) {
            return s;
        }
        let sess = self
            .sessions
            .login(&self.users, "root", now)
            .expect("root always exists");
        self.root = sess.id;
        sess
    }

    // -----------------------------------------------------------------
    // directory (operator provisioning, outside the wire protocol —
    // the protocol path is `Request::AddUser`, admin-gated)
    // -----------------------------------------------------------------

    /// Ensure a (non-admin) account exists; idempotent.
    pub fn add_user(&mut self, login: &str) {
        let _ = self.users.add_user(login, false);
    }

    /// Admin-gated account creation (the `add_user` protocol op).
    pub fn add_user_as(
        &mut self,
        sid: SessionId,
        login: &str,
        admin: bool,
    ) -> Result<(), DalekError> {
        let now = self.now();
        self.admin_session(sid, now)?;
        self.users.add_user(login, admin)?;
        Ok(())
    }

    // -----------------------------------------------------------------
    // accessors
    // -----------------------------------------------------------------

    pub fn now(&self) -> SimTime {
        self.kernel.now()
    }

    /// Read-only view of the controller (reports, node tables, tests).
    pub fn slurm(&self) -> &Slurm {
        &self.slurm.ctl
    }

    /// Read-only view of the streaming sampler (rolling telemetry;
    /// tests assert its `materialized_samples()` counter to prove the
    /// query and telemetry paths stay closed-form).
    pub fn sampler(&self) -> &StreamingSampler {
        &self.sampler
    }

    /// Read-only view of the periodic frontend services.
    pub fn services(&self) -> &ServiceRack {
        &self.services
    }

    /// Read-only view of the flow network.
    pub fn net(&self) -> &FlowNet {
        &self.net
    }

    /// Read-only view of the app engine (`dalek::app` programs).
    pub fn apps(&self) -> &AppEngine {
        &self.apps
    }

    pub fn has_runtime(&self) -> bool {
        self.runtime.is_some()
    }

    pub fn runtime(&self) -> Option<&PjRtRuntime> {
        self.runtime.as_ref()
    }

    /// Deterministic sub-RNG for workload generators.
    pub fn fork_rng(&mut self, label: &str) -> Xoshiro256 {
        self.rng.fork(label)
    }

    // -----------------------------------------------------------------
    // the kernel dispatch loop
    // -----------------------------------------------------------------

    /// Apply queued §4.3 power actions, then pop-and-route every event
    /// due at or before `t` and advance the unified clock to `t`. The
    /// only dispatch loop in the system; every advancing surface
    /// (`run_until`, `srun`, `salloc`, submissions) passes through it,
    /// so queued admin actions take effect at the next advance no
    /// matter who drives the clock.
    fn drive(&mut self, t: SimTime) {
        self.apply_power_actions();
        // app notices may be queued from a submission that started a
        // job before any event fired
        self.pump_apps();
        let mut last_pump = self.kernel.now();
        while let Some((now, ev)) = self.kernel.pop_due(t) {
            self.dispatch(now, ev);
            // any event can start an app job (boot completions, job
            // completions freeing nodes) or reprice one (governor
            // ticks): hand the notices to the engine at this timestamp
            self.pump_apps();
            // pace the event plane through long drives so telemetry
            // cursors never fall behind the rolling-history horizon
            if now.since(last_pump) >= EVENT_PUMP_INTERVAL {
                self.pump_events();
                last_pump = now;
            }
        }
        self.kernel.advance_to(t);
        self.slurm.ctl.sync_clock(self.kernel.now());
        // sessions that expired during this advance are torn down now
        // (subscriptions dropped, salloc allocations released) — an
        // absent client must not keep its reservation to the limit
        let now = self.kernel.now();
        for sid in self.sessions.take_expired(now) {
            self.teardown_session(sid);
        }
        // fan the lifecycle/power notices out to subscribed sessions
        // and cut any telemetry windows now due
        self.pump_events();
    }

    /// Drain the scheduler's app notices into the engine at the
    /// kernel's current time.
    fn pump_apps(&mut self) {
        let now = self.kernel.now();
        self.apps.pump(
            &mut self.slurm.ctl,
            &mut self.net,
            &self.topo,
            &mut self.kernel,
            now,
        );
    }

    fn dispatch(&mut self, now: SimTime, ev: ClusterEvent) {
        match ev {
            ClusterEvent::Sched(e) => {
                // a preemption grace expiry mirrors the fault path's
                // checkpoint ordering: bank a phase-structured victim's
                // completed BSP iterations *before* the eviction
                // discards the engine run, and trim its work ledger
                // *after* (the run-end index rekeys from the current
                // spec at eviction time)
                let victim = match e {
                    SchedEvent::PreemptGrace(id) => self
                        .apps
                        .checkpoint(&mut self.net, &mut self.kernel, id)
                        .map(|iters| (id, iters)),
                    _ => None,
                };
                self.services.observe_sched(&mut self.kernel, &e, now);
                self.slurm.ctl.handle_event(&mut self.kernel, e, now);
                if let Some((id, iters)) = victim {
                    self.slurm.ctl.checkpoint_app(id, iters);
                }
            }
            ClusterEvent::Service(e) => {
                self.services
                    .on_event(&mut self.kernel, e, now, &self.slurm.ctl)
            }
            ClusterEvent::Net(_) => {
                let done = self.net.on_event(&mut self.kernel, now);
                if !done.is_empty() {
                    // a drained collective flow may complete a BSP phase
                    self.apps.on_flows_done(
                        &mut self.slurm.ctl,
                        &mut self.net,
                        &self.topo,
                        &mut self.kernel,
                        &done,
                        now,
                    );
                }
            }
            ClusterEvent::Policy(PolicyEvent::GovernorTick) => self.on_governor_tick(now),
            ClusterEvent::Fault(e) => self.on_fault_event(now, e),
            ClusterEvent::App(e) => self.apps.on_event(
                &mut self.slurm.ctl,
                &mut self.net,
                &self.topo,
                &mut self.kernel,
                e,
                now,
            ),
        }
    }

    /// One §3.6 governor control step: fold the scheduler's pending
    /// power transitions into the rolling-telemetry window (no sample
    /// materialization — this works identically in unsampled runs),
    /// read the measured rolling watts, and let the governor plan and
    /// actuate. Re-arms itself until the budget is cleared.
    fn on_governor_tick(&mut self, now: SimTime) {
        self.sampler.fold_rolling(self.slurm.ctl.transitions(), now);
        let rolling = self.sampler.rolling_mean_w(self.governor.window, now);
        let budget = self.governor.budget_w();
        let rearm = self
            .governor
            .tick(&mut self.slurm.ctl, &mut self.kernel, rolling, now);
        if rearm {
            let period = self.governor.period;
            self.kernel
                .schedule_at(now + period, PolicyEvent::GovernorTick);
        }
        // stage the control step for `PowerEvents` subscribers (routed
        // by the next pump, same timestamp)
        if let Some(b) = budget {
            if self.subs.values().any(|s| s.power_events) {
                self.pending_power.push((
                    now,
                    PowerEventKind::GovernorTick {
                        rolling_w: rolling,
                        budget_w: b,
                        throttle: self.governor.stats.last_throttle,
                    },
                ));
                if rolling > b * (1.0 + self.governor.tolerance) {
                    self.pending_power.push((
                        now,
                        PowerEventKind::BudgetViolation {
                            rolling_w: rolling,
                            budget_w: b,
                        },
                    ));
                }
            }
        }
    }

    /// One `dalek::faults` plan edge: inject or recover the armed
    /// fault. Node-plane faults route through the scheduler (which
    /// evicts, settles and requeues); the api layer's only added duty
    /// is the BSP checkpoint — banking a phase-structured victim's
    /// completed iterations *before* the eviction discards the engine
    /// run. Link-plane faults re-rate the host's NIC on the flow
    /// network and never touch the scheduler.
    fn on_fault_event(&mut self, now: SimTime, ev: FaultEvent) {
        let (idx, inject) = match ev {
            FaultEvent::Inject(i) => (i, true),
            FaultEvent::Recover(i) => (i, false),
        };
        // a replaced plan can leave stale edges on the kernel: ignore
        let Some(armed) = self.fault_plan.get(idx) else {
            return;
        };
        let kind = armed.spec.kind;
        let name = armed.spec.node.clone();
        let node_idx = armed.node_idx;
        let link = armed.link;
        let fired = armed.fired;
        if let Some((host, nominal)) = link {
            let FaultKind::LinkDegrade { fraction } = kind else {
                unreachable!("link entries only arm LinkDegrade");
            };
            if !inject && !fired {
                return;
            }
            let bps = if inject { nominal * fraction } else { nominal };
            self.net.set_host_nic_bps(&mut self.kernel, host, bps);
            self.fault_plan[idx].fired = inject;
            self.pending_faults.push((now, name, kind, inject));
            return;
        }
        let Some(ni) = node_idx else { return };
        if inject {
            // an ad-hoc injection may already hold the node: leave it
            // alone entirely — checkpointing (which cancels the engine
            // run) and then failing to inject would kill a healthy job
            if self.slurm.ctl.node_fault(ni).is_some() {
                return;
            }
            let nf = match kind {
                FaultKind::Crash => NodeFault::Crashed,
                // hold_w is captured from the live draw at injection
                FaultKind::Hang => NodeFault::Hung { hold_w: 0.0 },
                FaultKind::Brownout { floor_w } => NodeFault::Brownout { floor_w },
                FaultKind::Throttle { factor } => NodeFault::Throttled { factor },
                FaultKind::LinkDegrade { .. } => unreachable!("handled above"),
            };
            // only crash/hang evict; brownout/throttle leave the job in
            // place, so its engine run must keep running
            let evicts = matches!(kind, FaultKind::Crash | FaultKind::Hang);
            let victim = if evicts {
                self.slurm.ctl.node_info(ni).running
            } else {
                None
            };
            let iters =
                victim.and_then(|id| self.apps.checkpoint(&mut self.net, &mut self.kernel, id));
            if self.slurm.ctl.inject_fault(&mut self.kernel, ni, nf, now) {
                self.fault_plan[idx].fired = true;
                if let (Some(id), Some(iters)) = (victim, iters) {
                    self.slurm.ctl.checkpoint_app(id, iters);
                }
            }
        } else {
            // only this entry's own injection is ours to undo
            if fired {
                let _ = self.slurm.ctl.recover_fault(&mut self.kernel, ni, now);
            }
        }
        // eviction/recovery may start queued work (possibly app jobs)
        self.pump_apps();
    }

    /// Arm a seeded [`FaultPlan`] on the kernel — operator-level, like
    /// trace replay (the admin wire surface is `Request::InjectFault`,
    /// one fault at a time). The whole plan is validated and resolved
    /// before anything is scheduled, so a bad entry arms nothing.
    /// Returns the number of faults armed. Entries whose instants are
    /// already past fire at the next advance.
    pub fn install_fault_plan(&mut self, plan: &FaultPlan) -> Result<usize, DalekError> {
        plan.validate().map_err(DalekError::BadRequest)?;
        let mut armed = Vec::with_capacity(plan.faults.len());
        for spec in &plan.faults {
            let entry = match spec.kind {
                FaultKind::LinkDegrade { .. } => {
                    let host = self
                        .topo
                        .by_name(&spec.node)
                        .or_else(|| self.topo.by_name(&format!("{}.dalek", spec.node)))
                        .ok_or_else(|| {
                            DalekError::BadRequest(format!("unknown host `{}`", spec.node))
                        })?;
                    ArmedFault {
                        spec: spec.clone(),
                        node_idx: None,
                        link: Some((host, self.net.host_nic_bps(host))),
                        fired: false,
                    }
                }
                _ => {
                    let ni = self.slurm.ctl.node_index(&spec.node).ok_or_else(|| {
                        DalekError::Slurm(crate::slurm::scheduler::SlurmError::UnknownNode(
                            spec.node.clone(),
                        ))
                    })?;
                    ArmedFault {
                        spec: spec.clone(),
                        node_idx: Some(ni),
                        link: None,
                        fired: false,
                    }
                }
            };
            armed.push(entry);
        }
        let now = self.now();
        let base = self.fault_plan.len();
        for (i, entry) in armed.into_iter().enumerate() {
            let at = entry.spec.at.max(now);
            let rec = entry.spec.recovers_at().max(now);
            self.kernel.schedule_at(at, FaultEvent::Inject(base + i));
            self.kernel.schedule_at(rec, FaultEvent::Recover(base + i));
            self.fault_plan.push(entry);
        }
        Ok(self.fault_plan.len() - base)
    }

    /// Arm one fault right now for `duration` — the admin wire surface
    /// (`Request::InjectFault`) and a convenience for tests.
    pub fn inject_fault_now(
        &mut self,
        sid: SessionId,
        node: &str,
        kind: FaultKind,
        duration: SimTime,
    ) -> Result<(), DalekError> {
        let now = self.now();
        self.admin_session(sid, now)?;
        if duration == SimTime::ZERO {
            return Err(DalekError::BadRequest(
                "fault `duration_s` must be positive".into(),
            ));
        }
        let plan = FaultPlan {
            seed: 0,
            faults: vec![FaultSpec {
                at: now,
                duration,
                node: node.into(),
                kind,
            }],
        };
        self.install_fault_plan(&plan)?;
        // the injection edge is due at `now`: deliver it immediately so
        // the admin's next poll already sees the fault state
        self.drive(now);
        Ok(())
    }

    /// Feed the scheduler's drained power transitions to the streaming
    /// sampler, emitting every due sample batch up to the present.
    fn pump_samples(&mut self) {
        let to = self.kernel.now();
        let transitions = self.slurm.ctl.transitions();
        self.sampler.pump_cluster(transitions, to, &mut self.energy);
        self.slurm.ctl.clear_transitions();
        self.sampler.transitions_cleared();
    }

    /// Apply queued §4.3 manual power actions to the node FSMs (the
    /// scheduler refuses actions that would kill running work).
    fn apply_power_actions(&mut self) {
        let now = self.kernel.now();
        for action in self.energy.drain_actions() {
            let (node, on) = match action {
                PowerAction::On(n) => (n, true),
                PowerAction::Off(n) => (n, false),
            };
            // outcome (applied / already-there / refused) is best-effort
            // by design: the §4.3 queue has no reply channel
            let _ = self.slurm.ctl.admin_power(&mut self.kernel, &node, on, now);
        }
    }

    // -----------------------------------------------------------------
    // the streaming event plane
    // -----------------------------------------------------------------

    /// Route the scheduler's drained lifecycle/actuation notices to the
    /// subscribed outboxes and cut any telemetry windows now due.
    /// Called after every dispatch; with no subscriber it only clears
    /// the notice buffers (they must not grow without bound).
    fn pump_events(&mut self) {
        let jnotices = self.slurm.ctl.take_job_notices();
        let pnotices = self.slurm.ctl.take_power_notices();
        let fnotices = self.slurm.ctl.take_fault_notices();
        let staged = std::mem::take(&mut self.pending_power);
        let staged_faults = std::mem::take(&mut self.pending_faults);
        if self.subs.is_empty() {
            return;
        }
        // job lifecycle → JobEvents (owner-scoped; admins see all)
        for n in &jnotices {
            let owner = self.slurm.ctl.job(n.job).map(|j| j.spec.user.clone());
            let kind = match n.what {
                JobLifecycle::Queued => JobEventKind::Queued,
                JobLifecycle::Started => JobEventKind::Started,
                JobLifecycle::Requeued => JobEventKind::Requeued,
                JobLifecycle::Preempted => JobEventKind::Preempted,
                JobLifecycle::Resumed => JobEventKind::Resumed,
                JobLifecycle::Repriced { rate } => JobEventKind::Repriced { rate },
                JobLifecycle::Finished { state, energy_j } => JobEventKind::Finished {
                    state,
                    joules: energy_j,
                },
            };
            for s in self.subs.values_mut().filter(|s| s.job_events) {
                if s.admin || owner.as_deref() == Some(s.user.as_str()) {
                    s.outbox.push(Event::Job {
                        at: n.at,
                        job: n.job,
                        kind,
                    });
                }
            }
        }
        // §3.6 actuations + staged governor steps → PowerEvents
        if self.subs.values().any(|s| s.power_events) {
            let mut power: Vec<(SimTime, PowerEventKind)> = Vec::new();
            for p in &pnotices {
                power.push((
                    p.at,
                    PowerEventKind::CapActuated {
                        node: self.slurm.ctl.node_name(p.node).to_string(),
                        cpu_cap_w: p.cpu_cap_w,
                        gpu_cap_w: p.gpu_cap_w,
                        powersave: p.powersave,
                    },
                ));
            }
            power.extend(staged);
            power.sort_by_key(|(at, _)| *at); // stable: ties keep order
            for s in self.subs.values_mut().filter(|s| s.power_events) {
                for (at, kind) in &power {
                    s.outbox.push(Event::Power {
                        at: *at,
                        kind: kind.clone(),
                    });
                }
            }
        }
        // fault injection/recovery edges → FaultEvents. Scheduler-side
        // (node-plane) notices and staged link-plane edges merge into
        // one time-ordered stream; the kind mapping recovers the knob
        // parameters the scheduler bound at injection (a hang's hold_w
        // is physics, not plan input, so it stays scheduler-internal).
        if self.subs.values().any(|s| s.fault_events) {
            let mut faults = staged_faults;
            for n in &fnotices {
                let kind = match n.fault {
                    NodeFault::Crashed => FaultKind::Crash,
                    NodeFault::Hung { .. } => FaultKind::Hang,
                    NodeFault::Brownout { floor_w } => FaultKind::Brownout { floor_w },
                    NodeFault::Throttled { factor } => FaultKind::Throttle { factor },
                };
                faults.push((
                    n.at,
                    self.slurm.ctl.node_name(n.node).to_string(),
                    kind,
                    n.injected,
                ));
            }
            faults.sort_by_key(|(at, ..)| *at); // stable: ties keep order
            for s in self.subs.values_mut().filter(|s| s.fault_events) {
                for (at, node, kind, injected) in &faults {
                    s.outbox.push(Event::Fault {
                        at: *at,
                        node: node.clone(),
                        kind: *kind,
                        injected: *injected,
                    });
                }
            }
        }
        // decimated telemetry windows, cut from the rolling piecewise
        // history — no sample materialization on this path
        if self.subs.values().any(|s| s.telemetry.is_some()) {
            let now = self.kernel.now();
            self.sampler.fold_rolling(self.slurm.ctl.transitions(), now);
            let horizon_start = SimTime(now.as_ns().saturating_sub(ROLLING_HORIZON.as_ns()));
            let sampler = &self.sampler;
            for s in self.subs.values_mut() {
                let Some((period, start)) = s.telemetry else {
                    continue;
                };
                let mut next_t = start;
                // windows that aged past the retained history cannot be
                // integrated truthfully: skip them (rounding up, so the
                // cursor lands at or past the horizon) and say so
                if next_t < horizon_start {
                    let behind = horizon_start.since(next_t).as_ns();
                    let missed = behind.div_ceil(period.as_ns());
                    next_t = SimTime(next_t.as_ns() + missed * period.as_ns());
                    s.outbox.lag(missed);
                }
                while SimTime(next_t.as_ns() + period.as_ns()) <= now {
                    let end = SimTime(next_t.as_ns() + period.as_ns());
                    let energy_j = sampler.span_energy_j(next_t, end);
                    s.outbox.push(Event::Telemetry {
                        from: next_t,
                        to: end,
                        mean_w: energy_j / period.as_secs_f64(),
                        energy_j,
                    });
                    next_t = end;
                }
                s.telemetry = Some((period, next_t));
            }
        }
        // standing DQL queries → QueryEvents. Cadenced queries fire on
        // their sim-time grid; edge-triggered ones whenever this round
        // carried job/power notices. Delta suppression: a result equal
        // to the last delivery is not re-sent.
        if self.subs.values().any(|s| !s.standing.is_empty()) {
            let now = self.kernel.now();
            let edge = !jnotices.is_empty() || !pnotices.is_empty();
            self.sampler.fold_rolling(self.slurm.ctl.transitions(), now);
            let slurm = &self.slurm.ctl;
            let sampler = &self.sampler;
            let energy = &self.energy;
            let net = &self.net;
            let topo = &self.topo;
            for s in self.subs.values_mut() {
                let SessionSubs {
                    user,
                    admin,
                    standing,
                    outbox,
                    ..
                } = s;
                let scope = if *admin { None } else { Some(user.as_str()) };
                for q in standing.iter_mut() {
                    if !q.due(now, edge) {
                        continue;
                    }
                    let tree = ClusterTree::new(slurm, sampler, energy, net, topo, now, scope);
                    // evaluation errors are skipped: the cadence stays
                    // deterministic and an error has no delta to carry
                    let Ok(out) = crate::query::eval(&tree, &q.expr) else {
                        continue;
                    };
                    let encoded = crate::query::output_json(&out);
                    if q.last.as_ref() == Some(&encoded) {
                        continue;
                    }
                    q.last = Some(encoded.clone());
                    outbox.push(Event::Query {
                        at: now,
                        expr: q.canonical.clone(),
                        result: encoded,
                    });
                }
            }
        }
    }

    /// Open a typed event channel on a session. `PowerEvents` and
    /// `FaultEvents` are admin-only (the actuation and fault planes
    /// are infrastructure views; non-admins see fault consequences on
    /// their own jobs as `JobEvents` requeues).
    /// `Telemetry` takes a client-chosen decimation rate; the window
    /// period must fit the sampler's 120 s rolling-history horizon.
    /// Re-subscribing to `Telemetry` restarts the cursor at `now`.
    pub fn subscribe(
        &mut self,
        sid: SessionId,
        channel: Channel,
        rate_hz: Option<f64>,
    ) -> Result<(), DalekError> {
        let now = self.now();
        if channel == Channel::QueryEvents {
            // the channel is stood up per-expression, not bare
            return Err(DalekError::BadRequest(
                "subscribing to `query_events` requires an `expr` \
                 (the standing query to register)"
                    .into(),
            ));
        }
        let sess = match channel {
            Channel::PowerEvents | Channel::FaultEvents => self.admin_session(sid, now)?,
            _ => self.session(sid, now)?,
        };
        let cap = self.outbox_cap;
        let entry = self
            .subs
            .entry(sid)
            .or_insert_with(|| SessionSubs::new(sess.login.clone(), sess.admin, cap));
        match channel {
            Channel::JobEvents => entry.job_events = true,
            Channel::PowerEvents => entry.power_events = true,
            Channel::FaultEvents => entry.fault_events = true,
            Channel::QueryEvents => unreachable!("rejected above"),
            Channel::Telemetry => {
                let rate = rate_hz.unwrap_or(1.0);
                if !rate.is_finite() || rate <= 0.0 {
                    return Err(DalekError::BadRequest(format!(
                        "telemetry rate must be a positive number of Hz, got {rate}"
                    )));
                }
                let period = SimTime::from_secs_f64(1.0 / rate);
                // a quarter of the rolling horizon: with the event
                // plane pumped at least every ~64 s (paced drives +
                // the unconditional NTP tick), a cursor can then never
                // slip past the retained history between pumps — a
                // window is either integrated truthfully or explicitly
                // skipped as lag, never silently wrong
                let max_period = SimTime(ROLLING_HORIZON.as_ns() / 4);
                if period > max_period {
                    return Err(DalekError::BadRequest(format!(
                        "telemetry period {} s exceeds the supported maximum of {} s \
                         (a quarter of the {} s rolling-history horizon)",
                        period.as_secs_f64(),
                        max_period.as_secs_f64(),
                        ROLLING_HORIZON.as_secs_f64()
                    )));
                }
                if period.as_ns() == 0 {
                    return Err(DalekError::BadRequest(format!(
                        "telemetry rate {rate} Hz is finer than the ns clock"
                    )));
                }
                entry.telemetry = Some((period, now));
            }
        }
        Ok(())
    }

    /// Register a standing DQL query on the `query_events` channel.
    /// With a `rate_hz` the expression re-evaluates on that
    /// deterministic sim-time cadence; without one it re-evaluates on
    /// job/power edges. Results are owner-scoped exactly like one-shot
    /// queries, delta-suppressed, and delivered through the session's
    /// bounded outbox (lag semantics included). Each call adds one
    /// query; `unsubscribe` on the channel clears them all.
    pub fn subscribe_query(
        &mut self,
        sid: SessionId,
        expr: &str,
        rate_hz: Option<f64>,
    ) -> Result<(), DalekError> {
        let now = self.now();
        let sess = self.session(sid, now)?;
        let parsed = QueryExpr::parse(expr)?;
        let period = match rate_hz {
            None => None,
            Some(r) => {
                if !r.is_finite() || r <= 0.0 {
                    return Err(DalekError::BadRequest(format!(
                        "standing-query rate must be a positive number of Hz, got {r}"
                    )));
                }
                let p = SimTime::from_secs_f64(1.0 / r);
                if p.as_ns() == 0 {
                    return Err(DalekError::BadRequest(format!(
                        "standing-query rate {r} Hz is finer than the ns clock"
                    )));
                }
                Some(p)
            }
        };
        let cap = self.outbox_cap;
        let entry = self
            .subs
            .entry(sid)
            .or_insert_with(|| SessionSubs::new(sess.login.clone(), sess.admin, cap));
        entry.standing.push(StandingQuery::new(parsed, period, now));
        Ok(())
    }

    /// Close one channel; buffered events remain pollable. Idempotent.
    pub fn unsubscribe(&mut self, sid: SessionId, channel: Channel) -> Result<(), DalekError> {
        let now = self.now();
        self.session(sid, now)?;
        if let Some(s) = self.subs.get_mut(&sid) {
            match channel {
                Channel::JobEvents => s.job_events = false,
                Channel::PowerEvents => s.power_events = false,
                Channel::FaultEvents => s.fault_events = false,
                Channel::Telemetry => s.telemetry = None,
                Channel::QueryEvents => s.standing.clear(),
            }
        }
        Ok(())
    }

    /// Drain up to `max` buffered events from a session's outbox (a
    /// pending overflow signal leads as [`Event::Lagged`]).
    pub fn take_events(&mut self, sid: SessionId, max: usize) -> Vec<Event> {
        self.subs
            .get_mut(&sid)
            .map(|s| s.outbox.drain(max))
            .unwrap_or_default()
    }

    /// Buffered (not yet polled) event count of a session.
    pub fn pending_events(&self, sid: SessionId) -> usize {
        self.subs.get(&sid).map(|s| s.outbox.len()).unwrap_or(0)
    }

    /// Retarget the per-session outbox bound (default 256). Applies to
    /// existing and future subscriptions; shrinking drops the oldest
    /// buffered events and counts them as lag.
    pub fn set_outbox_capacity(&mut self, cap: usize) {
        self.outbox_cap = cap.max(1);
        for s in self.subs.values_mut() {
            s.outbox.set_cap(self.outbox_cap);
        }
    }

    fn mint_ticket(&mut self) -> Ticket {
        let t = self.next_ticket;
        self.next_ticket += 1;
        Ticket(t)
    }

    // -----------------------------------------------------------------
    // job control (sessions)
    // -----------------------------------------------------------------

    fn owner_for(&self, sess: &Session, requested: &Option<String>) -> Result<String, DalekError> {
        match requested {
            Some(u) if *u != sess.login => {
                if !sess.admin {
                    return Err(DalekError::AdminOnly);
                }
                self.users.user(u)?; // must exist
                Ok(u.clone())
            }
            _ => Ok(sess.login.clone()),
        }
    }

    fn spec_from_request(
        &mut self,
        owner: &str,
        req: &JobRequest,
    ) -> Result<JobSpec, DalekError> {
        if req.nodes == 0 {
            return Err(DalekError::BadRequest("`nodes` must be at least 1".into()));
        }
        match (&req.payload, &req.app) {
            (Some(_), Some(_)) => Err(DalekError::BadRequest(
                "a job cannot carry both a `payload` and an `app` program".into(),
            )),
            (Some(payload), None) => {
                // duration comes from the payload grounding, but an
                // explicit client time limit is still honored
                let mut spec =
                    self.payload_spec(owner, &req.partition, req.nodes, payload, req.iters)?;
                if let Some(tl) = req.time_limit {
                    spec.time_limit = tl;
                }
                Ok(spec)
            }
            (None, Some(app)) => {
                // the work ledger comes from the program (validated
                // against the rank count at submission); a stated
                // duration would be silently dropped, so refuse it
                if req.duration != SimTime::ZERO {
                    return Err(DalekError::BadRequest(
                        "app jobs derive their work from the program; omit `duration_s`".into(),
                    ));
                }
                let mut spec = JobSpec::app(owner, &req.partition, app.clone(), req.nodes);
                if let Some(tl) = req.time_limit {
                    spec.time_limit = tl;
                }
                Ok(spec)
            }
            (None, None) => Ok(JobSpec {
                user: owner.into(),
                partition: req.partition.clone(),
                nodes: req.nodes,
                duration: req.duration,
                time_limit: req.time_limit.unwrap_or(SimTime(
                    req.duration
                        .as_ns()
                        .saturating_mul(4)
                        .saturating_add(60_000_000_000),
                )),
                payload: None,
                activity: Activity::cpu_only(0.95),
                app: None,
            }),
        }
    }

    /// Build a payload-backed spec: execute the AOT artifact once for
    /// real (grounding + checksum), then size `iters` iterations on the
    /// target partition's roofline.
    fn payload_spec(
        &mut self,
        owner: &str,
        partition: &str,
        nodes: u32,
        payload: &str,
        iters: u64,
    ) -> Result<JobSpec, DalekError> {
        let rt = self.runtime.as_mut().ok_or(DalekError::NoRuntime)?;
        let report = rt
            .execute(payload, self.cfg.seed ^ iters)
            .map_err(|e| DalekError::Runtime(format!("{e:#}")))?;
        if !report.output_sum.is_finite() {
            return Err(DalekError::Runtime(format!(
                "payload `{payload}` produced non-finite output"
            )));
        }
        let spec_part = resolve_partition(partition).ok_or_else(|| {
            DalekError::Slurm(crate::slurm::scheduler::SlurmError::UnknownPartition(
                partition.into(),
            ))
        })?;
        // GPU-heavy payloads run on the dGPU where one exists
        let on_gpu = spec_part.node.dgpu.is_some()
            && (payload.starts_with("gemm") || payload.starts_with("cnn"));
        let (roofline, eff, activity) = if on_gpu {
            (
                spec_part.node.dgpu.as_ref().expect("checked").peak_f32(),
                GPU_EFFICIENCY,
                Activity {
                    cpu: 0.3,
                    dgpu: 0.95,
                    igpu: 0.0,
                },
            )
        } else {
            (
                spec_part
                    .node
                    .cpu
                    .peak_ops_accumulated(crate::hw::cpu::Instr::FmaF32),
                CPU_EFFICIENCY,
                Activity::cpu_only(0.95),
            )
        };
        let total_flops = report.flops as f64 * iters as f64;
        let per_node = total_flops / nodes as f64;
        let secs = per_node / (roofline * eff);
        let duration = SimTime::from_secs_f64(secs.max(1e-3));
        Ok(JobSpec {
            user: owner.into(),
            partition: partition.into(),
            nodes,
            duration,
            time_limit: duration + SimTime::from_mins(10),
            payload: Some(payload.into()),
            activity,
            app: None,
        })
    }

    /// sbatch for an already-validated session (single validation per
    /// request; the MUNGE per-RPC round-trip still happens in sbatch).
    fn submit_as(
        &mut self,
        sess: &Session,
        spec: JobSpec,
        now: SimTime,
    ) -> Result<JobId, DalekError> {
        if spec.user != sess.login && !sess.admin {
            return Err(DalekError::AdminOnly);
        }
        self.users.user(&spec.user)?; // owner must exist
        // drain events due before the submission instant, then queue
        self.drive(now.max(self.now()));
        let id = self.slurm.sbatch(&mut self.kernel, sess.uid, spec, now)?;
        self.pump_apps(); // the job may have started on warm nodes
        self.pump_events();
        Ok(id)
    }

    fn request_as(
        &mut self,
        sess: &Session,
        req: &JobRequest,
        now: SimTime,
    ) -> Result<JobId, DalekError> {
        let owner = self.owner_for(sess, &req.user)?;
        let spec = self.spec_from_request(&owner, req)?;
        self.drive(now.max(self.now()));
        let id = self.slurm.sbatch(&mut self.kernel, sess.uid, spec, now)?;
        self.pump_apps(); // the job may have started on warm nodes
        self.pump_events();
        Ok(id)
    }

    /// sbatch through a session: queue and return the job id. The spec's
    /// owner must be the session user unless the session is an admin's.
    pub fn submit_spec(
        &mut self,
        sid: SessionId,
        spec: JobSpec,
        now: SimTime,
    ) -> Result<JobId, DalekError> {
        let sess = self.session(sid, now)?;
        self.submit_as(&sess, spec, now)
    }

    /// The `submit_job` protocol op.
    pub fn submit_request(
        &mut self,
        sid: SessionId,
        req: &JobRequest,
        now: SimTime,
    ) -> Result<JobId, DalekError> {
        let sess = self.session(sid, now)?;
        self.request_as(&sess, req, now)
    }

    /// The nonblocking `run_job` protocol op (srun, v2): queue the job
    /// and return a [`Ticket`] immediately — the cluster clock does
    /// not advance past the submission instant. Progress is delivered
    /// on the `JobEvents` channel; the old blocking semantics are a
    /// thin client-side wait on top ([`ClusterApi::wait_job`], or the
    /// composed [`ClusterApi::run_request`]). Non-admin submissions
    /// keep the srun horizon clamp on their time limit, so waiting on
    /// the ticket later is bounded exactly like the old blocking call.
    pub fn run_ticket(
        &mut self,
        sid: SessionId,
        req: &JobRequest,
        now: SimTime,
    ) -> Result<(Ticket, JobId), DalekError> {
        let sess = self.session(sid, now)?;
        let owner = self.owner_for(&sess, &req.user)?;
        let mut spec = self.spec_from_request(&owner, req)?;
        if !sess.admin {
            spec.time_limit = spec.time_limit.min(NON_ADMIN_SRUN_HORIZON);
        }
        self.drive(now.max(self.now()));
        let id = self.slurm.sbatch(&mut self.kernel, sess.uid, spec, now)?;
        self.pump_apps(); // the job may have started on warm nodes
        self.pump_events();
        Ok((self.mint_ticket(), id))
    }

    /// The thin client-side wait that rebuilds blocking `srun` on a
    /// ticket: drive the whole cluster in strides until the job is
    /// terminal. Semantics (deadline, orphan cancellation, stride) are
    /// exactly the old blocking `run_job`'s — a ticket+wait run
    /// reproduces its timestamps and joules bit-for-bit. Non-admins
    /// may wait only on their own jobs (waiting advances the shared
    /// clock, the capability the `advance` op restricts) and are
    /// bounded by the srun horizon from `issued`.
    pub fn wait_job(
        &mut self,
        sid: SessionId,
        id: JobId,
        issued: SimTime,
    ) -> Result<(JobId, JobState), DalekError> {
        let now0 = self.now();
        let sess = self.session(sid, now0)?;
        let (owner, limit_clamped) = {
            let job = self.slurm.ctl.job(id).ok_or(DalekError::UnknownJob(id))?;
            (
                job.spec.user.clone(),
                job.spec.time_limit <= NON_ADMIN_SRUN_HORIZON,
            )
        };
        if owner != sess.login && !sess.admin {
            return Err(DalekError::AdminOnly);
        }
        // srun drives the shared sim clock; bound both the job's own
        // runtime and the total advance (queue wait included) for
        // non-admins — the unbounded version is the admin `advance` op
        let deadline = if sess.admin {
            None
        } else {
            Some(issued.max(now0) + NON_ADMIN_SRUN_HORIZON)
        };
        // block: advance the whole cluster in strides until terminal
        loop {
            let state = self.slurm.ctl.job(id).expect("checked above").state;
            if matches!(
                state,
                JobState::Completed | JobState::Timeout | JobState::Cancelled
            ) {
                return Ok((id, state));
            }
            let before = self.now();
            if deadline.is_some_and(|d| before >= d) {
                if state == JobState::Pending {
                    // deadline hit while still queued: don't leave an
                    // unreferencable orphan under the user's name
                    let _ = self.slurm.ctl.cancel(id, before);
                    self.pump_events();
                    return Err(DalekError::Deadline(id));
                }
                // A started srun-ticket job has its time limit clamped
                // to the horizon and — with the §3.6 rate floored at
                // MIN_RATE — terminates in bounded wall time, so (like
                // the old blocking srun, which only ever saw clamped
                // specs) the loop keeps blocking for it: the horizon
                // bounds the queue wait only. But wait_job also accepts
                // any owned `submit_job` id, whose limit is unclamped —
                // blocking on one would hand a non-admin an unbounded
                // shared-clock advance (the capability the `advance` op
                // restricts). Stop waiting instead: the job keeps
                // running, and the client can wait again or follow it
                // through JobEvents.
                if !limit_clamped {
                    return Err(DalekError::Deadline(id));
                }
            }
            // every queued job drains in finite sim time (durations are
            // capped by their time limits), so striding forward always
            // terminates; non-admin calls are additionally bounded by
            // the deadline above
            self.drive(before + SRUN_STRIDE);
        }
    }

    /// The old blocking srun, rebuilt on the nonblocking parts:
    /// ticket, then wait.
    pub fn run_request(
        &mut self,
        sid: SessionId,
        req: &JobRequest,
        now: SimTime,
    ) -> Result<(JobId, JobState), DalekError> {
        let (_ticket, id) = self.run_ticket(sid, req, now)?;
        self.wait_job(sid, id, now)
    }

    /// The nonblocking `alloc_nodes` protocol op (salloc, v2): queue
    /// the reservation and return a [`Ticket`] immediately. The
    /// allocation is registered against the session — logout or expiry
    /// releases it ([`ClusterApi::logout`]). `JobEvents` report when it
    /// starts; [`ClusterApi::wait_alloc`] rebuilds the blocking
    /// semantics (and grants interactive SSH).
    pub fn alloc_ticket(
        &mut self,
        sid: SessionId,
        req: &JobRequest,
        now: SimTime,
    ) -> Result<(Ticket, JobId), DalekError> {
        let sess = self.session(sid, now)?;
        let owner = self.owner_for(&sess, &req.user)?;
        let spec = self.spec_from_request(&owner, req)?;
        self.drive(now.max(self.now()));
        let id = self.slurm.sbatch(&mut self.kernel, sess.uid, spec, now)?;
        self.pump_apps();
        self.pump_events();
        self.session_allocs.entry(sid).or_default().push(id);
        Ok((self.mint_ticket(), id))
    }

    /// The blocking half of salloc: drive the cluster until the
    /// allocation exists (bounded by the §3.4 boot budget), grant
    /// interactive SSH through the login gate, and return the node
    /// names. Non-admins may wait only on their own allocations.
    pub fn wait_alloc(
        &mut self,
        sid: SessionId,
        id: JobId,
    ) -> Result<(JobId, Vec<String>), DalekError> {
        let now0 = self.now();
        let sess = self.session(sid, now0)?;
        let (user, limit) = {
            let job = self.slurm.ctl.job(id).ok_or(DalekError::UnknownJob(id))?;
            (job.spec.user.clone(), job.spec.time_limit)
        };
        if user != sess.login && !sess.admin {
            return Err(DalekError::AdminOnly);
        }
        // advance until the allocation exists (≤ boot budget)
        let deadline = now0 + self.slurm.ctl.power_policy.max_boot_delay + SimTime::from_mins(10);
        while self.slurm.ctl.job(id).expect("checked above").state == JobState::Pending
            && self.now() < deadline
        {
            let t = self.now() + SimTime::from_secs(10);
            self.drive(t);
        }
        let (state, allocated) = {
            let job = self.slurm.ctl.job(id).expect("checked above");
            (job.state, job.allocated.clone())
        };
        // the boot budget elapsed with the job still queued — that is a
        // failed allocation on this surface. A job that already ran to
        // termination during the wait loop DID hold its allocation, so
        // only never-allocated states are failures.
        if matches!(state, JobState::Pending | JobState::Cancelled) {
            let now = self.now();
            let _ = self.slurm.ctl.cancel(id, now); // don't leave it queued
            self.pump_events();
            return Err(DalekError::Incomplete);
        }
        let infos = self.slurm.ctl.node_infos();
        let nodes: Vec<String> = allocated.iter().map(|&i| infos[i].name.clone()).collect();
        // grant interactive SSH through the §3.5 login gate for the
        // allocation's lifetime (only while it actually holds nodes)
        if matches!(state, JobState::Configuring | JobState::Running) {
            let until = self.now() + limit;
            for n in &nodes {
                self.slurm.gate.grant(n, &user, until);
            }
        }
        Ok((id, nodes))
    }

    /// The old blocking salloc, rebuilt on the nonblocking parts:
    /// ticket, then wait.
    pub fn alloc_request(
        &mut self,
        sid: SessionId,
        req: &JobRequest,
        now: SimTime,
    ) -> Result<(JobId, Vec<String>), DalekError> {
        let (_ticket, id) = self.alloc_ticket(sid, req, now)?;
        self.wait_alloc(sid, id)
    }

    /// squeue-style job lookup (any authenticated user).
    pub fn job_info(&mut self, sid: SessionId, id: JobId) -> Result<JobView, DalekError> {
        let now = self.now();
        self.session(sid, now)?;
        let job = self.slurm.ctl.job(id).ok_or(DalekError::UnknownJob(id))?;
        Ok(JobView {
            job: job.id,
            user: job.spec.user.clone(),
            partition: job.spec.partition.clone(),
            state: job.state,
            nodes: job.spec.nodes,
            submitted: job.submitted,
            started: job.started,
            finished: job.finished,
        })
    }

    /// scancel: the owner or an admin may cancel.
    pub fn cancel(&mut self, sid: SessionId, id: JobId) -> Result<(), DalekError> {
        let now = self.now();
        let sess = self.session(sid, now)?;
        let owner = self
            .slurm
            .ctl
            .job(id)
            .ok_or(DalekError::UnknownJob(id))?
            .spec
            .user
            .clone();
        if owner != sess.login && !sess.admin {
            return Err(DalekError::AdminOnly);
        }
        self.slurm.ctl.cancel(id, now)?;
        self.pump_events();
        Ok(())
    }

    // -----------------------------------------------------------------
    // energy platform (§4.3, sessions)
    // -----------------------------------------------------------------

    /// Retrieve measured samples — all users. `decimate = n` keeps every
    /// n-th sample; returns `(total_in_window, kept)`.
    pub fn samples(
        &mut self,
        sid: SessionId,
        node: &str,
        probe: u8,
        window: (SimTime, SimTime),
        decimate: u32,
    ) -> Result<(u64, Vec<Sample>), DalekError> {
        let now = self.now();
        self.session(sid, now)?;
        let all = self.energy.samples(node, probe, window)?;
        let total = all.len() as u64;
        let step = decimate.max(1) as usize;
        Ok((total, all.into_iter().step_by(step).collect()))
    }

    /// Tag samples via the GPIO inputs — all users.
    pub fn set_tag(
        &mut self,
        sid: SessionId,
        node: &str,
        line: u8,
        high: bool,
    ) -> Result<(), DalekError> {
        let now = self.now();
        self.session(sid, now)?;
        Ok(self.energy.set_gpio_tag(node, line, high)?)
    }

    /// Manual node power control — administrators only. The action is
    /// queued (§4.3) and applied to the node FSM at the next
    /// [`ClusterApi::run_until`] tick; the scheduler refuses actions
    /// that would kill running work.
    pub fn power(&mut self, sid: SessionId, node: &str, on: bool) -> Result<(), DalekError> {
        let now = self.now();
        self.admin_session(sid, now)?;
        self.energy.board(node)?; // must name a real board
        let action = if on {
            PowerAction::On(node.into())
        } else {
            PowerAction::Off(node.into())
        };
        self.energy.queue_power(action);
        Ok(())
    }

    // -----------------------------------------------------------------
    // DQL (`dalek::query`, sessions)
    // -----------------------------------------------------------------

    /// Evaluate one DQL expression against the live virtual cluster
    /// tree (the `query` protocol op). Owner-scoped: non-admin
    /// sessions see only their own jobs and quota account. Returns the
    /// canonical spelling of the expression and the shaped result; no
    /// samples are materialized and no state is cloned.
    pub fn query(
        &mut self,
        sid: SessionId,
        expr: &str,
    ) -> Result<(String, QueryOutput), DalekError> {
        let now = self.now();
        let sess = self.session(sid, now)?;
        let parsed = QueryExpr::parse(expr)?;
        // windowed aggregates read the rolling piecewise history: fold
        // the pending transitions so the window reaches `now`
        self.sampler.fold_rolling(self.slurm.ctl.transitions(), now);
        let scope = if sess.admin {
            None
        } else {
            Some(sess.login.as_str())
        };
        let tree = ClusterTree::new(
            &self.slurm.ctl,
            &self.sampler,
            &self.energy,
            &self.net,
            &self.topo,
            now,
            scope,
        );
        let out = crate::query::eval(&tree, &parsed)?;
        Ok((parsed.to_string(), out))
    }

    /// Evaluate a trusted, programmatically-built expression against
    /// the unscoped tree and return its scalar number. The legacy
    /// aggregate surfaces (`query_energy`, `power_report`) are thin
    /// sugar over this — one evaluator, pinned equivalent by
    /// construction.
    fn eval_scalar_num(&mut self, expr: &QueryExpr) -> Result<f64, DalekError> {
        let now = self.now();
        self.sampler.fold_rolling(self.slurm.ctl.transitions(), now);
        let tree = ClusterTree::new(
            &self.slurm.ctl,
            &self.sampler,
            &self.energy,
            &self.net,
            &self.topo,
            now,
            None,
        );
        match crate::query::eval(&tree, expr)? {
            QueryOutput::Scalar(QueryValue::Num(x)) => Ok(x),
            other => Err(DalekError::InvalidQuery(format!(
                "`{expr}` did not evaluate to a number: {other:?}"
            ))),
        }
    }

    /// Measured energy: whole cluster, one node, or one node windowed.
    /// Sugar over the DQL evaluator: `sum(nodes.<n|*>.measured.energy_j
    /// [, window])` against the virtual tree.
    pub fn query_energy(
        &mut self,
        sid: SessionId,
        node: Option<&str>,
        window: Option<(SimTime, SimTime)>,
    ) -> Result<f64, DalekError> {
        let now = self.now();
        self.session(sid, now)?;
        if let Some(n) = node {
            self.energy.board(n)?; // keep the typed NoBoard surface
        }
        self.eval_scalar_num(&measured_energy_expr(node, window))
    }

    // -----------------------------------------------------------------
    // energy-aware scheduling (§3.6 governor + §6.2 policies)
    // -----------------------------------------------------------------

    /// Set (or clear with `None`) the cluster power budget —
    /// administrators only. A fresh budget arms the governor's periodic
    /// tick on the kernel; the governor then holds the measured rolling
    /// cluster draw at or under the budget by capping the busy nodes
    /// (which genuinely slows their jobs), and disarms itself once the
    /// budget is cleared.
    pub fn set_power_budget(
        &mut self,
        sid: SessionId,
        watts: Option<f64>,
    ) -> Result<PowerReport, DalekError> {
        let now = self.now();
        self.admin_session(sid, now)?;
        if let Some(w) = watts {
            if !w.is_finite() || w <= 0.0 {
                return Err(DalekError::BadRequest(format!(
                    "power budget must be a positive number of watts, got {w}"
                )));
            }
        }
        if self.governor.set_budget(watts) {
            self.kernel.schedule_at(now, PolicyEvent::GovernorTick);
        }
        Ok(self.power_report_now())
    }

    /// Select a partition's §6.2 placement policy — administrators only.
    pub fn set_policy(
        &mut self,
        sid: SessionId,
        partition: &str,
        policy: PlacementPolicy,
    ) -> Result<(), DalekError> {
        let now = self.now();
        self.admin_session(sid, now)?;
        Ok(self.slurm.ctl.set_placement(partition, policy)?)
    }

    /// Provision a §6.2 time/energy quota account — administrators
    /// only. Submissions by `user` are then admission-checked, and
    /// completions settle the measured joules against the budget.
    pub fn set_quota(
        &mut self,
        sid: SessionId,
        user: &str,
        time_budget_s: f64,
        energy_budget_j: f64,
    ) -> Result<(), DalekError> {
        let now = self.now();
        self.admin_session(sid, now)?;
        self.users.user(user)?; // must exist in the directory
        self.slurm
            .ctl
            .quota
            .set_account(user, time_budget_s, energy_budget_j);
        Ok(())
    }

    /// Configure a user's fair-share weight — administrators only. The
    /// first non-zero share switches the scheduler from legacy
    /// submission order to priority order (aging + share deficit) and
    /// arms preemption; setting every share back to zero restores the
    /// legacy order bit-identically.
    pub fn set_shares(
        &mut self,
        sid: SessionId,
        user: &str,
        share: f64,
    ) -> Result<(), DalekError> {
        let now = self.now();
        self.admin_session(sid, now)?;
        self.users.user(user)?; // must exist in the directory
        if !share.is_finite() || share < 0.0 {
            return Err(DalekError::BadRequest(format!(
                "fair-share must be a finite non-negative weight, got {share}"
            )));
        }
        self.slurm.ctl.fairshare.set_share(user, share);
        Ok(())
    }

    /// Governor telemetry/actuation snapshot — any authenticated user.
    pub fn power_report(&mut self, sid: SessionId) -> Result<PowerReport, DalekError> {
        let now = self.now();
        self.session(sid, now)?;
        Ok(self.power_report_now())
    }

    fn power_report_now(&mut self) -> PowerReport {
        // the report's aggregate fields are DQL sugar: the same tree
        // queries any client can issue, summed in the same node-index
        // order the sampler folds in (equivalence pinned in tests)
        let window = self.governor.window;
        let rolling_w = self
            .eval_scalar_num(&rolling_watts_expr(window))
            .expect("static expression over live nodes");
        let cluster_w = self
            .eval_scalar_num(&parse_static("cluster.watts"))
            .expect("static expression");
        let capped = self
            .eval_scalar_num(&parse_static("count(nodes[capped=true])"))
            .expect("static expression");
        PowerReport {
            budget_w: self.governor.budget_w(),
            rolling_w,
            window_s: window.as_secs_f64(),
            cluster_w,
            throttle: self.governor.stats.last_throttle,
            capped_nodes: capped as u32,
            governor_ticks: self.governor.stats.ticks,
            idle_shutdowns: self.governor.stats.idle_shutdowns,
        }
    }

    /// Read-only governor access (tuning knobs live behind
    /// [`ClusterApi::governor_mut`]).
    pub fn governor(&self) -> &PowerGovernor {
        &self.governor
    }

    /// Tune the governor (period, window, tolerance, idle power-down
    /// threshold) — operator-level configuration, not a wire op.
    pub fn governor_mut(&mut self) -> &mut PowerGovernor {
        &mut self.governor
    }

    /// Operator-level §3.6 knob actuation on one node (the governor's
    /// mechanism, exposed for heterogeneity experiments): RAPL package
    /// cap, dGPU cap (`None` clears), Powersave toggle. Reprices the
    /// running job; for a phase-structured job the app engine re-arms
    /// the current compute barrier at the new per-rank rates — a
    /// single capped rank delays the whole barrier.
    pub fn apply_power_knobs(
        &mut self,
        node: &str,
        cpu_cap: Option<f64>,
        gpu_cap: Option<f64>,
        powersave: bool,
    ) -> Result<(), DalekError> {
        let idx = self.slurm.ctl.node_index(node).ok_or_else(|| {
            DalekError::Slurm(crate::slurm::scheduler::SlurmError::UnknownNode(
                node.into(),
            ))
        })?;
        let now = self.now();
        self.slurm
            .ctl
            .apply_power_knobs(&mut self.kernel, idx, cpu_cap, gpu_cap, powersave, now);
        self.pump_apps(); // deliver the reprice notice to the engine
        self.pump_events(); // and the actuation to PowerEvents subscribers
        Ok(())
    }

    // -----------------------------------------------------------------
    // network (operator surface)
    // -----------------------------------------------------------------

    /// Start a bulk transfer between two hosts on the flow network; the
    /// completion rides the unified kernel. Host names accept both the
    /// short node form (`az4-n4090-0`) and the FQDN (`…​.dalek`).
    pub fn start_transfer(
        &mut self,
        src: &str,
        dst: &str,
        bytes: u64,
    ) -> Result<FlowId, DalekError> {
        let resolve = |topo: &Topology, name: &str| {
            topo.by_name(name)
                .or_else(|| topo.by_name(&format!("{name}.dalek")))
        };
        let s = resolve(&self.topo, src)
            .ok_or_else(|| DalekError::BadRequest(format!("unknown host `{src}`")))?;
        let d = resolve(&self.topo, dst)
            .ok_or_else(|| DalekError::BadRequest(format!("unknown host `{dst}`")))?;
        if s == d {
            return Err(DalekError::BadRequest("transfer to self".into()));
        }
        Ok(self.net.start_flow_on(&mut self.kernel, s, d, bytes))
    }

    // -----------------------------------------------------------------
    // runtime (sessions)
    // -----------------------------------------------------------------

    /// Execute an AOT payload on the PJRT runtime (best of `iters`).
    pub fn exec_payload(
        &mut self,
        sid: SessionId,
        payload: &str,
        seed: u64,
        iters: u32,
    ) -> Result<ExecReport, DalekError> {
        let now = self.now();
        self.session(sid, now)?;
        let rt = self.runtime.as_mut().ok_or(DalekError::NoRuntime)?;
        rt.execute_best_of(payload, seed, iters.max(1))
            .map_err(|e| DalekError::Runtime(format!("{e:#}")))
    }

    // -----------------------------------------------------------------
    // operator console — the same stack, driven through the built-in
    // root session (trace replay, benches, the CLI `run` command)
    // -----------------------------------------------------------------

    /// Submit a synthetic job as the operator, on behalf of `spec.user`
    /// (the account is provisioned if missing — site-admin style).
    pub fn submit(&mut self, spec: JobSpec, now: SimTime) -> Result<JobId, DalekError> {
        self.add_user(&spec.user);
        let root = self.root_session(now);
        self.submit_as(&root, spec, now)
    }

    /// Submit a payload-backed job as the operator: executes the AOT
    /// artifact once for real, then simulates `iters` iterations on the
    /// target partition's hardware.
    pub fn submit_payload(
        &mut self,
        user: &str,
        partition: &str,
        nodes: u32,
        payload: &str,
        iters: u64,
        now: SimTime,
    ) -> Result<JobId, DalekError> {
        self.add_user(user);
        let root = self.root_session(now);
        let req = JobRequest {
            partition: partition.into(),
            nodes,
            duration: SimTime::ZERO, // sized from the payload grounding
            time_limit: None,
            payload: Some(payload.into()),
            iters,
            user: Some(user.into()),
            app: None,
        };
        self.request_as(&root, &req, now)
    }

    /// Advance the whole cluster to `t`: apply queued §4.3 power
    /// actions, dispatch every due event (scheduler, network, services)
    /// through the unified kernel, and — when `sample` is set — stream
    /// the §4 probe samples for everything that happened since the last
    /// sampled advance. Sampling is segment-batched off the scheduler's
    /// power transitions, so it never misses energy regardless of how
    /// the clock advanced (submissions, unsampled runs), and costs time
    /// proportional to power changes rather than simulated seconds.
    pub fn run_until(&mut self, t: SimTime, sample: bool) {
        self.drive(t);
        if sample {
            self.pump_samples();
        }
    }

    /// Current summary.
    pub fn report(&self) -> ClusterReport {
        let samples = self
            .energy
            .boards()
            .map(|b| {
                (0..self.cfg.energy.probes_per_node as u8)
                    .filter_map(|p| b.store(p).ok())
                    .map(|s| s.total_samples())
                    .sum::<u64>()
            })
            .sum();
        ClusterReport {
            now: self.now(),
            jobs_completed: self.slurm.ctl.stats.completed,
            jobs_pending: self.slurm.ctl.pending_count(),
            cluster_watts: self.slurm.ctl.cluster_watts(),
            true_energy_j: self.slurm.ctl.total_energy_j(),
            measured_energy_j: self.energy.total_energy_j(),
            samples,
        }
    }

    // -----------------------------------------------------------------
    // the protocol dispatcher
    // -----------------------------------------------------------------

    /// Execute one typed request. `Login` needs no session; everything
    /// else requires a valid token.
    pub fn handle(
        &mut self,
        sid: Option<SessionId>,
        req: &Request,
    ) -> Result<Response, DalekError> {
        let now = self.now();
        if let Request::Login { user } = req {
            let sess = self.sessions.login(&self.users, user, now)?;
            return Ok(Response::Session {
                id: sess.id,
                user: sess.login,
                admin: sess.admin,
            });
        }
        let sid = sid.ok_or(DalekError::InvalidSession)?;
        match req {
            Request::Login { .. } => unreachable!("handled above"),
            Request::Logout => {
                if self.logout(sid) {
                    Ok(Response::LoggedOut)
                } else {
                    Err(DalekError::InvalidSession)
                }
            }
            Request::AddUser { user, admin } => {
                self.add_user_as(sid, user, *admin)?;
                Ok(Response::UserAdded { user: user.clone() })
            }
            Request::SubmitJob(r) => {
                let job = self.submit_request(sid, r, now)?;
                Ok(Response::Submitted { job })
            }
            Request::RunJob(r) => {
                let (ticket, job) = self.run_ticket(sid, r, now)?;
                Ok(Response::Ticket {
                    ticket: ticket.0,
                    job,
                })
            }
            Request::AllocNodes(r) => {
                let (ticket, job) = self.alloc_ticket(sid, r, now)?;
                Ok(Response::Ticket {
                    ticket: ticket.0,
                    job,
                })
            }
            Request::WaitJob { job } => {
                let (job, state) = self.wait_job(sid, *job, now)?;
                Ok(Response::JobRan { job, state })
            }
            Request::WaitAlloc { job } => {
                let (job, nodes) = self.wait_alloc(sid, *job)?;
                Ok(Response::Allocated { job, nodes })
            }
            Request::Subscribe {
                channel,
                rate_hz,
                expr,
            } => {
                match (channel, expr) {
                    (Channel::QueryEvents, Some(e)) => {
                        self.subscribe_query(sid, e, *rate_hz)?
                    }
                    (_, None) => self.subscribe(sid, *channel, *rate_hz)?,
                    (_, Some(_)) => {
                        return Err(DalekError::BadRequest(
                            "`expr` only applies to the `query_events` channel".into(),
                        ))
                    }
                }
                Ok(Response::Subscribed { channel: *channel })
            }
            Request::Query { expr } => {
                let (expr, result) = self.query(sid, expr)?;
                Ok(Response::QueryResult { expr, result })
            }
            Request::Unsubscribe { channel } => {
                self.unsubscribe(sid, *channel)?;
                Ok(Response::Unsubscribed { channel: *channel })
            }
            Request::PollEvents { max } => {
                self.session(sid, now)?;
                let events = self.take_events(sid, *max as usize);
                Ok(Response::Events { events })
            }
            Request::SetRateLimit { user, ops } => {
                // the budget itself lives in the multiplexing ApiServer
                // (which intercepts this op); the capability check and
                // the user's existence are validated here either way
                self.admin_session(sid, now)?;
                self.users.user(user)?;
                Ok(Response::RateLimitSet {
                    user: user.clone(),
                    ops: *ops,
                })
            }
            Request::SetShares { user, share } => {
                self.set_shares(sid, user, *share)?;
                Ok(Response::SharesSet {
                    user: user.clone(),
                    share: *share,
                })
            }
            Request::JobInfo { job } => Ok(Response::Job(self.job_info(sid, *job)?)),
            Request::CancelJob { job } => {
                self.cancel(sid, *job)?;
                Ok(Response::Cancelled { job: *job })
            }
            Request::QuerySamples {
                node,
                probe,
                from,
                to,
                decimate,
            } => {
                let (total, samples) =
                    self.samples(sid, node, *probe, (*from, *to), *decimate)?;
                Ok(Response::Samples {
                    node: node.clone(),
                    probe: *probe,
                    total,
                    samples,
                })
            }
            Request::QueryEnergy { node, window } => {
                let joules = self.query_energy(sid, node.as_deref(), *window)?;
                Ok(Response::Energy { joules })
            }
            Request::SetTag { node, line, high } => {
                self.set_tag(sid, node, *line, *high)?;
                Ok(Response::TagSet {
                    node: node.clone(),
                    line: *line,
                    high: *high,
                })
            }
            Request::Power { node, on } => {
                self.power(sid, node, *on)?;
                Ok(Response::PowerQueued {
                    node: node.clone(),
                    on: *on,
                })
            }
            Request::ClusterReport => {
                self.session(sid, now)?;
                let r = self.report();
                Ok(Response::Report {
                    now: r.now,
                    jobs_completed: r.jobs_completed,
                    jobs_pending: r.jobs_pending,
                    cluster_watts: r.cluster_watts,
                    true_energy_j: r.true_energy_j,
                    measured_energy_j: r.measured_energy_j,
                    samples: r.samples,
                })
            }
            Request::Advance { to, sample } => {
                self.admin_session(sid, now)?;
                self.run_until(*to, *sample);
                Ok(Response::Advanced { now: self.now() })
            }
            Request::ExecPayload {
                payload,
                iters,
                seed,
            } => {
                let r = self.exec_payload(sid, payload, *seed, *iters)?;
                Ok(Response::Executed {
                    payload: r.payload,
                    wall_s: r.wall_s,
                    flops: r.flops,
                    flops_per_sec: r.flops_per_sec,
                    output_sum: r.output_sum,
                })
            }
            Request::SetPowerBudget { watts } => {
                let r = self.set_power_budget(sid, *watts)?;
                Ok(power_report_response(r))
            }
            Request::SetPolicy { partition, policy } => {
                let p = PlacementPolicy::from_wire(policy).ok_or_else(|| {
                    DalekError::BadRequest(format!("unknown policy `{policy}`"))
                })?;
                self.set_policy(sid, partition, p)?;
                Ok(Response::PolicySet {
                    partition: partition.clone(),
                    policy: policy.clone(),
                })
            }
            Request::PowerReport => {
                let r = self.power_report(sid)?;
                Ok(power_report_response(r))
            }
            Request::InjectFault {
                node,
                kind,
                duration,
            } => {
                self.inject_fault_now(sid, node, *kind, *duration)?;
                Ok(Response::FaultInjected {
                    node: node.clone(),
                    kind: kind.label().into(),
                })
            }
        }
    }

    /// Execute one JSON envelope and encode the reply — the scriptable
    /// wire surface (`dalek api request.json`). Never panics on bad
    /// input: malformed requests and execution failures both come back
    /// as `{"ok": false, "error": ...}`.
    pub fn handle_json(&mut self, src: &str) -> String {
        let resp = match Request::parse(src) {
            Ok((sid, req)) => match self.handle(sid, &req) {
                Ok(r) => r,
                Err(e) => Response::from_error(&e),
            },
            Err(e) => Response::from_error(&e),
        };
        resp.to_json().to_string()
    }
}

/// Parse a DQL expression known valid at compile time.
fn parse_static(src: &str) -> QueryExpr {
    QueryExpr::parse(src).expect("static DQL expression")
}

/// `sum(nodes.*.power.watts, window=<w>)` — the governor's measured
/// rolling cluster draw, as a tree query.
fn rolling_watts_expr(window: SimTime) -> QueryExpr {
    let mut e = parse_static("sum(nodes.*.power.watts)");
    let QueryExpr::Agg { window: w, .. } = &mut e else {
        unreachable!("parsed an aggregate")
    };
    *w = Some(WindowSpec::Trailing(window));
    e
}

/// `sum(nodes.<n|*>.measured.energy_j[, window=a..b])` — the legacy
/// `query_energy` surface, as a tree query. Built programmatically so
/// node names never round-trip through the parser.
fn measured_energy_expr(node: Option<&str>, window: Option<(SimTime, SimTime)>) -> QueryExpr {
    use crate::query::{AggFunc, Path, SegKey, Segment};
    let seg = |key: SegKey| Segment { key, pred: None };
    let path = Path {
        segments: vec![
            seg(SegKey::Name("nodes".into())),
            seg(match node {
                Some(n) => SegKey::Name(n.into()),
                None => SegKey::Wildcard,
            }),
            seg(SegKey::Name("measured".into())),
            seg(SegKey::Name("energy_j".into())),
        ],
    };
    QueryExpr::Agg {
        func: AggFunc::Sum,
        path,
        window: window.map(|(a, b)| WindowSpec::Span(a, b)),
    }
}

fn power_report_response(r: PowerReport) -> Response {
    Response::PowerReport {
        budget_w: r.budget_w,
        rolling_w: r.rolling_w,
        window_s: r.window_s,
        cluster_w: r.cluster_w,
        throttle: r.throttle,
        capped_nodes: r.capped_nodes,
        governor_ticks: r.governor_ticks,
        idle_shutdowns: r.idle_shutdowns,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::power::PowerState;
    use crate::slurm::JobState;

    fn cluster() -> ClusterApi {
        ClusterApi::new(ClusterConfig::dalek_default(), None).unwrap()
    }

    fn artifacts_dir() -> Option<&'static str> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        std::path::Path::new(dir)
            .join("manifest.json")
            .exists()
            .then_some(dir)
    }

    #[test]
    fn builds_16_boards() {
        let c = cluster();
        assert_eq!(c.energy.boards().count(), 16);
        assert_eq!(c.sampler.node_count(), 16);
    }

    #[test]
    fn measured_energy_tracks_truth() {
        let mut c = cluster();
        c.submit(JobSpec::cpu("root", "az5-a890m", 2, 120), SimTime::ZERO)
            .unwrap();
        c.run_until(SimTime::from_mins(8), true);
        let r = c.report();
        assert!(r.samples > 0);
        assert!(r.true_energy_j > 0.0);
        // probes quantize to mW and add noise; agreement within 1%
        let rel = (r.measured_energy_j - r.true_energy_j).abs() / r.true_energy_j;
        assert!(rel < 0.01, "rel error {rel}: {r:?}");
    }

    #[test]
    fn sampling_rate_is_configured_1000_sps() {
        let mut c = cluster();
        c.run_until(SimTime::from_secs(10), true);
        let r = c.report();
        // 16 nodes x 1 probe x 1000 SPS x 10 s
        let expect = 16.0 * 1000.0 * 10.0;
        let got = r.samples as f64;
        assert!((got - expect).abs() / expect < 0.01, "{got} vs {expect}");
    }

    #[test]
    fn sampling_catches_up_over_unsampled_windows() {
        // the §4 guarantee: sampling never misses energy, regardless of
        // how the clock advanced — an unsampled stretch is streamed in
        // full on the next sampled advance
        let mut c = cluster();
        c.submit(JobSpec::cpu("root", "az5-a890m", 2, 120), SimTime::ZERO)
            .unwrap();
        c.run_until(SimTime::from_mins(4), false); // job runs unsampled
        assert_eq!(c.report().samples, 0);
        c.run_until(SimTime::from_mins(8), true); // catch-up
        let r = c.report();
        let expect = 16.0 * 1000.0 * 480.0;
        assert!((r.samples as f64 - expect).abs() / expect < 0.01);
        let rel = (r.measured_energy_j - r.true_energy_j).abs() / r.true_energy_j;
        assert!(rel < 0.01, "rel error {rel}");
    }

    #[test]
    fn unsampled_run_is_cheap_and_equivalent_in_truth() {
        let mut a = cluster();
        let mut b = cluster();
        a.submit(JobSpec::cpu("root", "az4-n4090", 4, 300), SimTime::ZERO)
            .unwrap();
        b.submit(JobSpec::cpu("root", "az4-n4090", 4, 300), SimTime::ZERO)
            .unwrap();
        a.run_until(SimTime::from_mins(30), false);
        b.run_until(SimTime::from_mins(30), true);
        let (ra, rb) = (a.report(), b.report());
        assert_eq!(ra.jobs_completed, rb.jobs_completed);
        assert!((ra.true_energy_j - rb.true_energy_j).abs() < 1e-6);
        assert_eq!(ra.samples, 0);
    }

    #[test]
    fn payload_job_runs_real_artifact_then_simulates() {
        let Some(dir) = artifacts_dir() else { return };
        let mut c = ClusterApi::new(ClusterConfig::dalek_default(), Some(dir)).unwrap();
        c.add_user("alice");
        let id = c
            .submit_payload("alice", "az4-n4090", 2, "gemm256", 50_000, SimTime::ZERO)
            .unwrap();
        c.run_until(SimTime::from_hours(2), false);
        let job = c.slurm().job(id).unwrap();
        assert_eq!(job.state, JobState::Completed, "{:?}", job.state);
        assert_eq!(job.spec.payload.as_deref(), Some("gemm256"));
        // GPU-backed duration: 50k x 33.5 MFLOP / 2 nodes on 4090s
        // (≈0.84 TFLOP/node over a ~25 TFLOP/s effective roofline)
        let d = job.spec.duration.as_secs_f64();
        assert!(d > 0.01 && d < 600.0, "duration {d}");
        // sanity: the same payload on the CPU-only partition is slower
        let id2 = c
            .submit_payload("alice", "az5-a890m", 2, "gemm256", 50_000, c.now())
            .unwrap();
        c.run_until(c.now() + SimTime::from_hours(4), false);
        let d2 = c.slurm().job(id2).unwrap().spec.duration.as_secs_f64();
        assert!(d2 > 5.0 * d, "CPU {d2} vs GPU {d}");
    }

    #[test]
    fn payload_requires_runtime() {
        let mut c = cluster();
        assert!(matches!(
            c.submit_payload("root", "az4-n4090", 1, "gemm256", 1, SimTime::ZERO),
            Err(DalekError::NoRuntime)
        ));
    }

    // ---- session semantics over the composed stack ----

    #[test]
    fn login_session_submit_flow() {
        let mut c = cluster();
        c.add_user("alice");
        let sid = c.login("alice").unwrap();
        let req = JobRequest {
            partition: "az5-a890m".into(),
            nodes: 1,
            duration: SimTime::from_secs(60),
            time_limit: None,
            payload: None,
            iters: 1,
            user: None,
            app: None,
        };
        let id = c.submit_request(sid, &req, SimTime::ZERO).unwrap();
        c.run_until(SimTime::from_mins(10), false);
        let v = c.job_info(sid, id).unwrap();
        assert_eq!(v.user, "alice");
        assert_eq!(v.state, JobState::Completed);
    }

    #[test]
    fn unknown_user_cannot_login() {
        let mut c = cluster();
        assert!(matches!(c.login("mallory"), Err(DalekError::Auth(_))));
    }

    #[test]
    fn non_admin_cannot_submit_on_behalf_nor_power() {
        let mut c = cluster();
        c.add_user("alice");
        c.add_user("bob");
        let sid = c.login("alice").unwrap();
        let mut req = JobRequest {
            partition: "az5-a890m".into(),
            nodes: 1,
            duration: SimTime::from_secs(30),
            time_limit: None,
            payload: None,
            iters: 1,
            user: Some("bob".into()),
            app: None,
        };
        assert!(matches!(
            c.submit_request(sid, &req, SimTime::ZERO),
            Err(DalekError::AdminOnly)
        ));
        req.user = None;
        assert!(c.submit_request(sid, &req, SimTime::ZERO).is_ok());
        assert!(matches!(
            c.power(sid, "az5-a890m-0", false),
            Err(DalekError::AdminOnly)
        ));
    }

    #[test]
    fn admin_powers_and_advances() {
        let mut c = cluster();
        let sid = c.login("root").unwrap();
        c.power(sid, "az5-a890m-0", false).unwrap();
        assert!(matches!(
            c.power(sid, "no-such-node", true),
            Err(DalekError::NoBoard(_))
        ));
        let r = c
            .handle(
                Some(sid),
                &Request::Advance {
                    to: SimTime::from_secs(30),
                    sample: true,
                },
            )
            .unwrap();
        assert!(matches!(r, Response::Advanced { now } if now >= SimTime::from_secs(30)));
    }

    #[test]
    fn queued_power_on_boots_suspended_node() {
        // §4.3 wiring: the queued action reaches the node FSM
        let mut c = cluster();
        let sid = c.login("root").unwrap();
        c.power(sid, "az5-a890m-0", true).unwrap();
        assert!(matches!(
            c.slurm().node_infos()[12].state,
            PowerState::Suspended
        ));
        c.run_until(SimTime::from_mins(3), false); // az5 boots in 70 s
        let info = &c.slurm().node_infos()[12];
        assert_eq!(info.name, "az5-a890m-0");
        assert!(
            matches!(info.state, PowerState::Idle { .. }),
            "{:?}",
            info.state
        );
        assert_eq!(info.boots, 1);
    }

    #[test]
    fn queued_power_off_transitions_node_fsm_ahead_of_policy() {
        let mut c = cluster();
        let id = c
            .submit(JobSpec::cpu("root", "az5-a890m", 1, 60), SimTime::ZERO)
            .unwrap();
        c.run_until(SimTime::from_mins(3), false); // boot 70 s + run 60 s
        assert_eq!(c.slurm().job(id).unwrap().state, JobState::Completed);
        let node = {
            let infos = c.slurm().node_infos();
            let i = c.slurm().job(id).unwrap().allocated[0];
            assert!(matches!(infos[i].state, PowerState::Idle { .. }));
            infos[i].name.clone()
        };
        let sid = c.login("root").unwrap();
        c.power(sid, &node, false).unwrap();
        // applied at the next tick, well before the 10-minute policy
        c.run_until(SimTime::from_mins(4), false);
        let info = c
            .slurm()
            .node_infos()
            .into_iter()
            .find(|n| n.name == node)
            .unwrap();
        assert!(
            matches!(info.state, PowerState::Suspended),
            "{:?}",
            info.state
        );
        assert_eq!(info.suspends, 1);
    }

    #[test]
    fn queued_power_off_never_kills_running_job() {
        let mut c = cluster();
        let id = c
            .submit(JobSpec::cpu("root", "az5-a890m", 4, 600), SimTime::ZERO)
            .unwrap();
        c.run_until(SimTime::from_mins(3), false); // running by now
        assert_eq!(c.slurm().job(id).unwrap().state, JobState::Running);
        let sid = c.login("root").unwrap();
        c.power(sid, "az5-a890m-0", false).unwrap();
        c.run_until(SimTime::from_mins(5), false);
        // refused: still allocated, job completes normally
        c.run_until(SimTime::from_mins(30), false);
        assert_eq!(c.slurm().job(id).unwrap().state, JobState::Completed);
    }

    #[test]
    fn samples_and_energy_through_session() {
        let mut c = cluster();
        c.submit(JobSpec::cpu("root", "az5-a890m", 2, 120), SimTime::ZERO)
            .unwrap();
        c.run_until(SimTime::from_secs(30), true);
        let sid = c.login("root").unwrap();
        let (total, kept) = c
            .samples(
                sid,
                "az5-a890m-0",
                0,
                (SimTime::ZERO, SimTime::from_secs(30)),
                10,
            )
            .unwrap();
        assert!(total > 0);
        assert!(kept.len() <= total as usize / 10 + 1);
        let j = c.query_energy(sid, None, None).unwrap();
        assert!(j > 0.0);
        let jn = c
            .query_energy(sid, Some("az5-a890m-0"), None)
            .unwrap();
        assert!(jn > 0.0 && jn <= j);
    }

    #[test]
    fn cancel_requires_owner_or_admin() {
        let mut c = cluster();
        c.add_user("alice");
        c.add_user("eve");
        let alice = c.login("alice").unwrap();
        let eve = c.login("eve").unwrap();
        let blocker = JobRequest {
            partition: "az4-n4090".into(),
            nodes: 4,
            duration: SimTime::from_secs(3600),
            time_limit: None,
            payload: None,
            iters: 1,
            user: None,
            app: None,
        };
        c.submit_request(alice, &blocker, SimTime::ZERO).unwrap();
        // the partition is fully reserved, so this one stays Pending
        let req = JobRequest {
            nodes: 1,
            duration: SimTime::from_secs(600),
            ..blocker
        };
        let id = c.submit_request(alice, &req, SimTime::ZERO).unwrap();
        assert_eq!(c.job_info(alice, id).unwrap().state, JobState::Pending);
        assert!(matches!(
            c.cancel(eve, id),
            Err(DalekError::AdminOnly)
        ));
        c.cancel(alice, id).unwrap();
        assert_eq!(c.job_info(alice, id).unwrap().state, JobState::Cancelled);
    }

    #[test]
    fn non_admin_srun_hits_deadline_behind_blocker() {
        let mut c = cluster();
        c.add_user("alice");
        // operator blocks the whole partition for two days
        c.submit(
            JobSpec::cpu("root", "az5-a890m", 4, 48 * 3600),
            SimTime::ZERO,
        )
        .unwrap();
        let sid = c.login("alice").unwrap();
        let req = JobRequest {
            partition: "az5-a890m".into(),
            nodes: 1,
            duration: SimTime::from_secs(60),
            time_limit: None,
            payload: None,
            iters: 1,
            user: None,
            app: None,
        };
        let e = c.run_request(sid, &req, SimTime::ZERO);
        let Err(DalekError::Deadline(id)) = e else {
            panic!("expected Deadline, got {e:?}");
        };
        // the orphan was cancelled, and the clock stopped near the horizon
        assert_eq!(c.job_info(sid, id).unwrap().state, JobState::Cancelled);
        assert!(c.now() <= NON_ADMIN_SRUN_HORIZON + SRUN_STRIDE);
    }

    #[test]
    fn transfers_ride_the_unified_kernel() {
        let mut c = cluster();
        c.start_transfer("az4-n4090-0", "az4-n4090-1", 1_000_000_000)
            .unwrap();
        assert_eq!(c.net().active_flows(), 1);
        // 8 Gbit over 2.5 GbE ≈ 3.2 s; drive the cluster past it
        c.run_until(SimTime::from_secs(10), false);
        assert_eq!(c.net().active_flows(), 0);
        assert_eq!(c.net().completed_flows, 1);
        assert!((c.net().delivered_bytes - 1e9).abs() < 1e6);
        assert!(matches!(
            c.start_transfer("nope", "az4-n4090-1", 1),
            Err(DalekError::BadRequest(_))
        ));
    }

    #[test]
    fn services_tick_on_the_shared_kernel() {
        let mut c = cluster();
        c.submit(JobSpec::cpu("root", "az5-a890m", 2, 120), SimTime::ZERO)
            .unwrap();
        c.run_until(SimTime::from_mins(5), false);
        // proberctl reported (2 nodes × ~2 min up at 1 Hz) and lit the strip
        assert!(c.services().readings >= 200, "{}", c.services().readings);
        assert!(c
            .services()
            .strip("az5-a890m")
            .unwrap()
            .node_count()
            >= 2);
        // NTP disciplined clocks throughout
        assert!(c.services().worst_ntp_offset_s > 0.0);
    }

    #[test]
    fn salloc_grants_ssh_through_login_gate() {
        let mut c = cluster();
        c.add_user("alice");
        let sid = c.login("alice").unwrap();
        let req = JobRequest {
            partition: "iml-ia770".into(),
            nodes: 2,
            duration: SimTime::from_secs(600),
            time_limit: None,
            payload: None,
            iters: 1,
            user: None,
            app: None,
        };
        let (id, nodes) = c.alloc_request(sid, &req, SimTime::ZERO).unwrap();
        assert_eq!(nodes.len(), 2);
        let job = c.slurm().job(id).unwrap();
        assert!(matches!(
            job.state,
            JobState::Configuring | JobState::Running
        ));
        let now = c.now();
        assert!(c.slurm.gate.try_ssh(&nodes[0], "alice", now));
        assert!(!c.slurm.gate.try_ssh(&nodes[0], "powerstate", now));
        // other partition's node: no grant
        assert!(!c.slurm.gate.try_ssh("az4-n4090-0", "alice", now));
    }

    #[test]
    fn power_budget_closes_the_loop_end_to_end() {
        let mut c = cluster();
        let sid = c.login("root").unwrap();
        // non-admins may read the report but not set the budget
        c.add_user("alice");
        let alice = c.login("alice").unwrap();
        assert!(matches!(
            c.set_power_budget(alice, Some(500.0)),
            Err(DalekError::AdminOnly)
        ));
        assert!(matches!(
            c.set_power_budget(sid, Some(-1.0)),
            Err(DalekError::BadRequest(_))
        ));
        let r = c.set_power_budget(sid, Some(180.0)).unwrap();
        assert_eq!(r.budget_w, Some(180.0));
        // saturate the az5 partition; the governor must pull the draw
        // down to the budget and stretch the job
        c.submit(JobSpec::cpu("root", "az5-a890m", 4, 600), SimTime::ZERO)
            .unwrap();
        c.run_until(SimTime::from_mins(5), false);
        let r = c.power_report(sid).unwrap();
        assert!(r.governor_ticks > 0);
        assert!(r.capped_nodes >= 4, "capped {}", r.capped_nodes);
        assert!(
            (r.cluster_w - 180.0).abs() < 1e-6,
            "draw {} vs budget 180",
            r.cluster_w
        );
        // rolling telemetry has settled onto the budget too
        assert!(r.rolling_w <= 180.0 * 1.05, "rolling {}", r.rolling_w);
        // capped work runs longer than nominal
        c.run_until(SimTime::from_mins(30), false);
        let job = c.slurm().jobs().next().unwrap();
        assert_eq!(job.state, JobState::Completed);
        assert!(job.run_time().unwrap() > SimTime::from_secs(620));
        // clearing the budget releases the caps at the next tick
        c.set_power_budget(sid, None).unwrap();
        c.run_until(c.now() + SimTime::from_secs(5), false);
        let r = c.power_report(sid).unwrap();
        assert_eq!(r.capped_nodes, 0);
        assert_eq!(r.budget_w, None);
    }

    #[test]
    fn power_budget_via_wire_protocol() {
        let mut c = cluster();
        let sid = c.login("root").unwrap();
        let r = c
            .handle(
                Some(sid),
                &Request::SetPowerBudget {
                    watts: Some(1200.0),
                },
            )
            .unwrap();
        assert!(matches!(
            r,
            Response::PowerReport {
                budget_w: Some(b),
                ..
            } if (b - 1200.0).abs() < 1e-12
        ));
        let r = c
            .handle(
                Some(sid),
                &Request::SetPolicy {
                    partition: "az5-a890m".into(),
                    policy: "energy_efficient".into(),
                },
            )
            .unwrap();
        assert!(matches!(r, Response::PolicySet { .. }));
        // unknown partition surfaces as a slurm error
        assert!(c
            .handle(
                Some(sid),
                &Request::SetPolicy {
                    partition: "nope".into(),
                    policy: "first_fit".into(),
                },
            )
            .is_err());
        let r = c.handle(Some(sid), &Request::PowerReport).unwrap();
        assert!(matches!(r, Response::PowerReport { .. }));
    }

    #[test]
    fn quota_settlement_through_the_cluster_api() {
        let mut c = cluster();
        c.add_user("alice");
        let root = c.login("root").unwrap();
        c.set_quota(root, "alice", 1e7, 1e9).unwrap();
        assert!(c.set_quota(root, "ghost", 1.0, 1.0).is_err());
        let alice = c.login("alice").unwrap();
        let req = JobRequest {
            partition: "az5-a890m".into(),
            nodes: 2,
            duration: SimTime::from_secs(120),
            time_limit: None,
            payload: None,
            iters: 1,
            user: None,
            app: None,
        };
        let id = c.submit_request(alice, &req, SimTime::ZERO).unwrap();
        c.run_until(SimTime::from_mins(10), false);
        let job = c.slurm().job(id).unwrap();
        assert_eq!(job.state, JobState::Completed);
        assert!(job.energy_j > 0.0);
        let acct = c.slurm().quota.account("alice").unwrap();
        assert!((acct.used_energy_j - job.energy_j).abs() < 1e-9);
        // an exhausted budget rejects the next submission
        c.set_quota(root, "alice", 1.0, 1.0).unwrap();
        assert!(matches!(
            c.submit_request(alice, &req, c.now()),
            Err(DalekError::Slurm(
                crate::slurm::scheduler::SlurmError::QuotaDenied { .. }
            ))
        ));
    }

    #[test]
    fn logout_revokes_capability() {
        let mut c = cluster();
        let sid = c.login("root").unwrap();
        assert!(c.logout(sid));
        assert!(matches!(
            c.handle(Some(sid), &Request::ClusterReport),
            Err(DalekError::InvalidSession)
        ));
        assert!(matches!(
            c.handle(None, &Request::ClusterReport),
            Err(DalekError::InvalidSession)
        ));
    }

    // ---- the streaming surface ----

    fn simple_req(partition: &str, nodes: u32, secs: u64) -> JobRequest {
        JobRequest {
            partition: partition.into(),
            nodes,
            duration: SimTime::from_secs(secs),
            time_limit: None,
            payload: None,
            iters: 1,
            user: None,
            app: None,
        }
    }

    #[test]
    fn run_ticket_is_nonblocking_and_wait_reproduces_blocking() {
        let mut c = cluster();
        c.add_user("alice");
        let sid = c.login("alice").unwrap();
        let (ticket, id) = c
            .run_ticket(sid, &simple_req("az5-a890m", 2, 300), SimTime::ZERO)
            .unwrap();
        assert_eq!(ticket, Ticket(1));
        // nonblocking: the clock did not advance past the submission
        assert_eq!(c.now(), SimTime::ZERO);
        assert_eq!(c.job_info(sid, id).unwrap().state, JobState::Configuring);
        // the thin wait drives to the terminal state, like old srun
        let (jid, state) = c.wait_job(sid, id, SimTime::ZERO).unwrap();
        assert_eq!(jid, id);
        assert_eq!(state, JobState::Completed);
        let job = c.slurm().job(id).unwrap();
        // az5 boots in 70 s; the run is exactly the nominal duration
        assert_eq!(job.started, Some(SimTime::from_secs(70)));
        assert_eq!(job.finished, Some(SimTime::from_secs(370)));
    }

    #[test]
    fn wait_job_is_owner_or_admin_scoped() {
        let mut c = cluster();
        c.add_user("alice");
        c.add_user("eve");
        let alice = c.login("alice").unwrap();
        let eve = c.login("eve").unwrap();
        let (_t, id) = c
            .run_ticket(alice, &simple_req("az5-a890m", 1, 60), SimTime::ZERO)
            .unwrap();
        // waiting advances the shared clock: not for strangers
        assert!(matches!(
            c.wait_job(eve, id, SimTime::ZERO),
            Err(DalekError::AdminOnly)
        ));
        let root = c.login("root").unwrap();
        assert!(c.wait_job(root, id, SimTime::ZERO).is_ok());
    }

    #[test]
    fn job_events_are_owner_scoped_and_carry_joules() {
        let mut c = cluster();
        c.add_user("alice");
        c.add_user("bob");
        let alice = c.login("alice").unwrap();
        let bob = c.login("bob").unwrap();
        c.subscribe(alice, Channel::JobEvents, None).unwrap();
        c.subscribe(bob, Channel::JobEvents, None).unwrap();
        let req = simple_req("az5-a890m", 2, 120);
        let id = c.submit_request(alice, &req, SimTime::ZERO).unwrap();
        c.run_until(SimTime::from_mins(10), false);
        let events = c.take_events(alice, usize::MAX);
        let kinds: Vec<&Event> = events.iter().collect();
        assert!(matches!(
            kinds[0],
            Event::Job { job, kind: JobEventKind::Queued, .. } if *job == id
        ));
        assert!(matches!(
            kinds[1],
            Event::Job { at, kind: JobEventKind::Started, .. }
                if *at == SimTime::from_secs(70)
        ));
        let Event::Job {
            kind: JobEventKind::Finished { state, joules },
            ..
        } = kinds[2]
        else {
            panic!("expected Finished, got {:?}", kinds[2]);
        };
        assert_eq!(*state, JobState::Completed);
        let settled = c.slurm().job(id).unwrap().energy_j;
        assert!((joules - settled).abs() < 1e-12, "{joules} vs {settled}");
        // bob subscribed too but owns nothing: no events
        assert!(c.take_events(bob, usize::MAX).is_empty());
        // an admin subscriber sees everyone's jobs
        let root = c.login("root").unwrap();
        c.subscribe(root, Channel::JobEvents, None).unwrap();
        c.submit_request(alice, &req, c.now()).unwrap();
        c.run_until(c.now() + SimTime::from_mins(10), false);
        assert!(!c.take_events(root, usize::MAX).is_empty());
    }

    #[test]
    fn outbox_overflow_signals_lagged() {
        let mut c = cluster();
        c.add_user("alice");
        let sid = c.login("alice").unwrap();
        c.set_outbox_capacity(4);
        c.subscribe(sid, Channel::JobEvents, None).unwrap();
        // 3 jobs x (queued + started + finished) = 9 events >> 4
        for k in 0..3 {
            let at = c.now() + SimTime::from_secs(k);
            c.submit_request(sid, &simple_req("az5-a890m", 1, 30), at)
                .unwrap();
        }
        c.run_until(c.now() + SimTime::from_mins(10), false);
        let events = c.take_events(sid, usize::MAX);
        let Event::Lagged { missed } = events[0] else {
            panic!("expected a leading Lagged, got {:?}", events[0]);
        };
        assert_eq!(missed, 5);
        assert_eq!(events.len(), 5); // the signal + the surviving 4
    }

    #[test]
    fn power_events_deliver_governor_and_actuations() {
        let mut c = cluster();
        let root = c.login("root").unwrap();
        c.subscribe(root, Channel::PowerEvents, None).unwrap();
        c.set_power_budget(root, Some(180.0)).unwrap();
        c.submit(JobSpec::cpu("root", "az5-a890m", 4, 300), SimTime::ZERO)
            .unwrap();
        c.run_until(SimTime::from_mins(4), false);
        let events = c.take_events(root, usize::MAX);
        let ticks = events
            .iter()
            .filter(|e| matches!(e, Event::Power { kind: PowerEventKind::GovernorTick { .. }, .. }))
            .count();
        let caps = events
            .iter()
            .filter(|e| matches!(e, Event::Power { kind: PowerEventKind::CapActuated { .. }, .. }))
            .count();
        assert!(ticks > 0, "no governor ticks in {} events", events.len());
        assert!(caps > 0, "no cap actuations in {} events", events.len());
        // timestamps are non-decreasing within the power stream
        let mut last = SimTime::ZERO;
        for e in &events {
            if let Event::Power { at, .. } = e {
                assert!(*at >= last);
                last = *at;
            }
        }
    }

    #[test]
    fn telemetry_windows_tile_the_timeline_without_samples() {
        let mut c = cluster();
        let root = c.login("root").unwrap();
        c.set_outbox_capacity(10_000);
        c.subscribe(root, Channel::Telemetry, Some(2.0)).unwrap();
        c.submit(JobSpec::cpu("root", "az5-a890m", 2, 60), SimTime::ZERO)
            .unwrap();
        c.run_until(SimTime::from_secs(30), false);
        c.run_until(SimTime::from_secs(100), false);
        let events = c.take_events(root, usize::MAX);
        // 2 Hz over 100 s = 200 windows, regardless of drive splits
        assert_eq!(events.len(), 200, "{events:?}");
        let mut expect_from = SimTime::ZERO;
        let mut total = 0.0;
        for e in &events {
            let Event::Telemetry { from, to, energy_j, .. } = e else {
                panic!("expected telemetry, got {e:?}");
            };
            assert_eq!(*from, expect_from, "windows must tile");
            assert_eq!(to.as_ns() - from.as_ns(), 500_000_000);
            total += energy_j;
            expect_from = *to;
        }
        // the tiled windows integrate the scheduler's exact truth
        let truth = c.slurm().total_energy_j();
        assert!(
            (total - truth).abs() < 1e-6,
            "telemetry {total} vs truth {truth}"
        );
        // and no sample was ever materialized
        assert_eq!(c.report().samples, 0);
    }

    #[test]
    fn power_events_and_rate_limit_are_admin_only() {
        let mut c = cluster();
        c.add_user("alice");
        let sid = c.login("alice").unwrap();
        assert!(matches!(
            c.subscribe(sid, Channel::PowerEvents, None),
            Err(DalekError::AdminOnly)
        ));
        assert!(matches!(
            c.handle(
                Some(sid),
                &Request::SetRateLimit {
                    user: "alice".into(),
                    ops: 1
                }
            ),
            Err(DalekError::AdminOnly)
        ));
        // non-admins may watch their own jobs and the telemetry
        assert!(c.subscribe(sid, Channel::JobEvents, None).is_ok());
        assert!(c.subscribe(sid, Channel::Telemetry, Some(1.0)).is_ok());
        // and bad telemetry rates are rejected
        assert!(matches!(
            c.subscribe(sid, Channel::Telemetry, Some(1.0 / 500.0)),
            Err(DalekError::BadRequest(_))
        ));
    }

    #[test]
    fn logout_releases_salloc_allocation_and_subscriptions() {
        let mut c = cluster();
        c.add_user("alice");
        let sid = c.login("alice").unwrap();
        c.subscribe(sid, Channel::JobEvents, None).unwrap();
        let (_t, id) = c
            .alloc_ticket(sid, &simple_req("iml-ia770", 2, 3600), SimTime::ZERO)
            .unwrap();
        let (_, nodes) = c.wait_alloc(sid, id).unwrap();
        assert_eq!(nodes.len(), 2);
        let now = c.now();
        assert!(c.slurm.gate.try_ssh(&nodes[0], "alice", now));
        // logout: the allocation must not survive the session
        assert!(c.logout(sid));
        let job = c.slurm().job(id).unwrap();
        assert_eq!(job.state, JobState::Cancelled);
        let now = c.now();
        assert!(!c.slurm.gate.try_ssh(&nodes[0], "alice", now));
        // nodes drain back to the pool (idle, then §3.4 suspend)
        c.run_until(now + SimTime::from_mins(15), false);
        for n in c.slurm().node_infos().iter().filter(|n| nodes.contains(&n.name)) {
            assert!(n.running.is_none());
        }
        // subscriptions died with the session
        assert_eq!(c.pending_events(sid), 0);
        assert!(c.take_events(sid, usize::MAX).is_empty());
    }

    #[test]
    fn logout_tears_down_a_running_app_program_cleanly() {
        // the salloc'd job carries a phase-structured program: teardown
        // must cancel the engine run (barrier timer, collective flows)
        // before releasing the nodes, or a later RankDue would complete
        // a cancelled job against freed nodes
        let mut c = cluster();
        c.add_user("alice");
        let sid = c.login("alice").unwrap();
        let app = crate::app::AppSpec::allreduce_loop("train", 120.0, 8_000_000, 50);
        let req = JobRequest {
            partition: "az5-a890m".into(),
            nodes: 2,
            duration: SimTime::ZERO,
            time_limit: None,
            payload: None,
            iters: 1,
            user: None,
            app: Some(app),
        };
        let (_t, id) = c.alloc_ticket(sid, &req, SimTime::ZERO).unwrap();
        c.run_until(SimTime::from_mins(3), false); // booted, program running
        assert_eq!(c.slurm().job(id).unwrap().state, JobState::Running);
        assert_eq!(c.apps().active_apps(), 1);
        assert!(c.logout(sid));
        assert_eq!(c.apps().active_apps(), 0, "engine run must be torn down");
        assert_eq!(c.slurm().job(id).unwrap().state, JobState::Cancelled);
        // drain far past where the program would have completed: no
        // stale timer fires, nothing panics, the job stays cancelled
        c.run_until(SimTime::from_hours(6), false);
        assert_eq!(c.slurm().job(id).unwrap().state, JobState::Cancelled);
        assert_eq!(c.net().active_flows(), 0);
    }

    // ---- the fault plane (dalek::faults) ----

    #[test]
    fn crash_requeues_running_job_and_fault_stream_reports_both_edges() {
        let mut c = cluster();
        let root = c.login("root").unwrap();
        c.set_outbox_capacity(10_000);
        c.subscribe(root, Channel::FaultEvents, None).unwrap();
        c.subscribe(root, Channel::JobEvents, None).unwrap();
        c.submit(JobSpec::cpu("root", "az5-a890m", 2, 600), SimTime::ZERO)
            .unwrap();
        c.run_until(SimTime::from_mins(2), false); // booted, running
        let victim = c
            .slurm()
            .node_infos()
            .iter()
            .find(|n| n.running.is_some())
            .expect("the job is running somewhere")
            .name
            .clone();
        let plan = FaultPlan {
            seed: 0,
            faults: vec![FaultSpec {
                at: c.now(),
                duration: SimTime::from_secs(120),
                node: victim.clone(),
                kind: FaultKind::Crash,
            }],
        };
        assert_eq!(c.install_fault_plan(&plan).unwrap(), 1);
        c.run_until(c.now() + SimTime::from_mins(40), false);
        let job = c.slurm().jobs().next().unwrap();
        assert_eq!(job.state, JobState::Completed);
        assert!(job.energy_j > 0.0);
        assert_eq!(c.slurm().stats.faults_injected, 1);
        assert_eq!(c.slurm().stats.fault_requeues, 1);
        let events = c.take_events(root, usize::MAX);
        let edges: Vec<(String, FaultKind, bool)> = events
            .iter()
            .filter_map(|e| match e {
                Event::Fault {
                    node,
                    kind,
                    injected,
                    ..
                } => Some((node.clone(), *kind, *injected)),
                _ => None,
            })
            .collect();
        assert_eq!(
            edges,
            vec![
                (victim.clone(), FaultKind::Crash, true),
                (victim.clone(), FaultKind::Crash, false),
            ]
        );
        assert!(
            events
                .iter()
                .any(|e| matches!(e, Event::Job { kind: JobEventKind::Requeued, .. })),
            "the eviction must surface as a Requeued job event"
        );
        // a bad plan arms nothing
        let overlap = FaultPlan {
            seed: 0,
            faults: vec![
                plan.faults[0].clone(),
                FaultSpec {
                    at: plan.faults[0].at + SimTime::from_secs(1),
                    ..plan.faults[0].clone()
                },
            ],
        };
        assert!(matches!(
            c.install_fault_plan(&overlap),
            Err(DalekError::BadRequest(_))
        ));
        let unknown = FaultPlan {
            seed: 0,
            faults: vec![FaultSpec {
                node: "nope".into(),
                ..plan.faults[0].clone()
            }],
        };
        assert!(c.install_fault_plan(&unknown).is_err());
    }

    #[test]
    fn crash_checkpoints_app_job_at_its_last_bsp_barrier() {
        let mut c = cluster();
        let root = c.login("root").unwrap();
        let app = crate::app::AppSpec::allreduce_loop("train", 30.0, 8_000_000, 10);
        let work = app.compute_work_s(); // 300 s of compute
        let spec = JobSpec {
            user: "root".into(),
            partition: "az5-a890m".into(),
            nodes: 2,
            duration: SimTime::from_secs_f64(work),
            time_limit: SimTime::from_secs_f64(work * 4.0 + 3600.0),
            payload: None,
            activity: Activity::cpu_only(0.9),
            app: Some(app),
        };
        let id = c.submit(spec, SimTime::ZERO).unwrap();
        // boot is 70 s, each iteration is 30 s compute + an allreduce:
        // by 4 min several barriers have been crossed
        c.run_until(SimTime::from_mins(4), false);
        assert_eq!(c.slurm().job(id).unwrap().state, JobState::Running);
        let victim = c
            .slurm()
            .node_infos()
            .iter()
            .find(|n| n.running == Some(id))
            .unwrap()
            .name
            .clone();
        c.inject_fault_now(root, &victim, FaultKind::Crash, SimTime::from_mins(2))
            .unwrap();
        // the eviction banked completed iterations into a trimmed spec:
        // the restart replays only the unfinished tail (the scheduler
        // may have re-placed the job synchronously during the eviction
        // — the trim must land regardless of the state it reached)
        let job = c.slurm().job(id).unwrap();
        assert_ne!(job.state, JobState::Completed);
        let left = job.spec.app.as_ref().unwrap().iterations;
        assert!(left < 10, "no iterations were checkpointed");
        assert!(left >= 1, "the in-flight iteration is never banked");
        assert_eq!(
            job.spec.duration,
            SimTime::from_secs_f64(30.0 * left as f64),
            "the work ledger must shrink with the checkpoint"
        );
        c.run_until(c.now() + SimTime::from_mins(40), false);
        assert_eq!(c.slurm().job(id).unwrap().state, JobState::Completed);
        assert_eq!(c.apps().active_apps(), 0);
        assert_eq!(c.slurm().stats.fault_requeues, 1);
    }

    #[test]
    fn fault_channel_and_wire_op_are_admin_scoped() {
        let mut c = cluster();
        c.add_user("alice");
        let alice = c.login("alice").unwrap();
        assert!(matches!(
            c.subscribe(alice, Channel::FaultEvents, None),
            Err(DalekError::AdminOnly)
        ));
        let inject = |node: &str, kind: FaultKind| Request::InjectFault {
            node: node.into(),
            kind,
            duration: SimTime::from_secs(60),
        };
        assert!(matches!(
            c.handle(Some(alice), &inject("az5-a890m-0", FaultKind::Crash)),
            Err(DalekError::AdminOnly)
        ));
        let root = c.login("root").unwrap();
        c.subscribe(root, Channel::FaultEvents, None).unwrap();
        let r = c
            .handle(
                Some(root),
                &inject("az5-a890m-0", FaultKind::Brownout { floor_w: 120.0 }),
            )
            .unwrap();
        assert!(matches!(
            r,
            Response::FaultInjected { ref kind, .. } if kind == "brownout"
        ));
        // the fault is live and already visible in the admin's outbox
        let ni = c.slurm().node_index("az5-a890m-0").unwrap();
        assert!(matches!(
            c.slurm().node_fault(ni),
            Some(NodeFault::Brownout { .. })
        ));
        assert!(c
            .take_events(root, usize::MAX)
            .iter()
            .any(|e| matches!(e, Event::Fault { injected: true, .. })));
        // unknown nodes and zero durations are typed refusals
        assert!(c.handle(Some(root), &inject("nope", FaultKind::Crash)).is_err());
        assert!(matches!(
            c.inject_fault_now(root, "az5-a890m-1", FaultKind::Crash, SimTime::ZERO),
            Err(DalekError::BadRequest(_))
        ));
        // recovery fires after the armed duration
        c.run_until(c.now() + SimTime::from_mins(2), false);
        assert!(c.slurm().node_fault(ni).is_none());
    }

    #[test]
    fn dql_exposes_fault_state_and_mtbf() {
        let mut c = cluster();
        let root = c.login("root").unwrap();
        let scalar = |out: &QueryOutput| match out {
            QueryOutput::Scalar(QueryValue::Num(x)) => *x,
            other => panic!("expected a numeric scalar, got {other:?}"),
        };
        // a fault-free cluster has no MTBF yet (Null, not 0 or ∞)
        let (_, out) = c.query(root, "cluster.mtbf_s").unwrap();
        assert!(matches!(out, QueryOutput::Scalar(QueryValue::Null)));
        let (_, out) = c.query(root, "cluster.faults_injected").unwrap();
        assert_eq!(scalar(&out), 0.0);
        let (_, out) = c.query(root, "nodes.az5-a890m-0.faults.active").unwrap();
        assert!(matches!(out, QueryOutput::Scalar(QueryValue::Bool(false))));
        c.run_until(SimTime::from_mins(10), false);
        c.inject_fault_now(
            root,
            "az5-a890m-0",
            FaultKind::Brownout { floor_w: 133.0 },
            SimTime::from_mins(5),
        )
        .unwrap();
        let (_, out) = c.query(root, "nodes.az5-a890m-0.faults.active").unwrap();
        assert!(matches!(out, QueryOutput::Scalar(QueryValue::Bool(true))));
        let (_, out) = c.query(root, "nodes.az5-a890m-0.faults.kind").unwrap();
        assert!(matches!(
            out,
            QueryOutput::Scalar(QueryValue::Str(ref s)) if s == "brownout"
        ));
        let (_, out) = c.query(root, "nodes.az5-a890m-0.faults.param").unwrap();
        assert_eq!(scalar(&out), 133.0);
        let (_, out) = c.query(root, "cluster.faults_injected").unwrap();
        assert_eq!(scalar(&out), 1.0);
        let (_, out) = c.query(root, "cluster.mtbf_s").unwrap();
        assert_eq!(scalar(&out), c.now().as_secs_f64());
        // recovery clears the subtree back to the quiet shape
        c.run_until(c.now() + SimTime::from_mins(6), false);
        let (_, out) = c.query(root, "nodes.az5-a890m-0.faults.kind").unwrap();
        assert!(matches!(out, QueryOutput::Scalar(QueryValue::Null)));
        // ... but the MTBF keeps aging on the same single failure
        let (_, out) = c.query(root, "cluster.mtbf_s").unwrap();
        assert_eq!(scalar(&out), c.now().as_secs_f64());
    }

    #[test]
    fn governor_routes_around_faulted_nodes_under_budget() {
        // the §3.6 loop through chaos: actuation skips crashed and
        // browned-out nodes (their draw is a constraint, not a knob)
        // while the budget still binds on the healthy remainder
        let mut c = cluster();
        let root = c.login("root").unwrap();
        c.set_power_budget(root, Some(150.0)).unwrap();
        c.inject_fault_now(root, "az5-a890m-3", FaultKind::Crash, SimTime::from_mins(30))
            .unwrap();
        c.inject_fault_now(
            root,
            "az5-a890m-2",
            FaultKind::Brownout { floor_w: 40.0 },
            SimTime::from_mins(30),
        )
        .unwrap();
        let id = c
            .submit(JobSpec::cpu("root", "az5-a890m", 2, 300), c.now())
            .unwrap();
        c.run_until(c.now() + SimTime::from_mins(10), false);
        let scalar = |c: &mut ClusterApi, expr: &str| {
            let (_, out) = c.query(root, expr).unwrap();
            match out {
                QueryOutput::Scalar(QueryValue::Num(x)) => x,
                QueryOutput::Scalar(QueryValue::Bool(b)) => b as u8 as f64,
                other => panic!("expected a scalar, got {other:?}"),
            }
        };
        // the governor kept ticking through the faults
        let r = c.power_report(root).unwrap();
        assert!(r.governor_ticks > 0);
        // faulted nodes were never actuated: a crashed node draws
        // nothing, a browned-out node is pinned at its PSU floor
        assert_eq!(scalar(&mut c, "nodes.az5-a890m-3.capped"), 0.0);
        assert_eq!(scalar(&mut c, "nodes.az5-a890m-2.capped"), 0.0);
        assert_eq!(scalar(&mut c, "nodes.az5-a890m-3.power.watts"), 0.0);
        assert!(scalar(&mut c, "nodes.az5-a890m-2.power.watts") >= 40.0 - 1e-9);
        // the job only ever landed on the two healthy nodes
        let faulted = [
            c.slurm().node_index("az5-a890m-2").unwrap(),
            c.slurm().node_index("az5-a890m-3").unwrap(),
        ];
        for j in c.slurm().jobs() {
            for ni in &j.allocated {
                assert!(!faulted.contains(ni), "placed work on a grounded node");
            }
        }
        // lift the budget (a 150 W cap over a ~144 W uncappable floor
        // can pin the survivors at MIN_RATE, which is legitimately
        // slow) and the healthy pair carries the job home
        c.set_power_budget(root, None).unwrap();
        c.run_until(SimTime::from_mins(40), false);
        assert_eq!(c.slurm().job(id).unwrap().state, JobState::Completed);
        // and after recovery the nodes are schedulable again
        assert!(c.slurm().node_fault(faulted[0]).is_none());
        assert!(c.slurm().node_fault(faulted[1]).is_none());
    }

    #[test]
    fn telemetry_cursor_at_exact_horizon_boundary_is_not_lagged() {
        // regression pin for the lag arithmetic at the 120 s boundary:
        // a cursor sitting exactly at `now - ROLLING_HORIZON` can still
        // integrate every window truthfully — the strict `<` must not
        // round it into a phantom `Lagged`
        let mut c = cluster();
        let root = c.login("root").unwrap();
        c.set_outbox_capacity(10_000);
        c.subscribe(root, Channel::Telemetry, Some(1.0)).unwrap();
        c.run_until(SimTime::from_secs(300), false);
        c.take_events(root, usize::MAX); // drop the catch-up windows
        let now = c.now();
        let hs = SimTime(now.as_ns() - ROLLING_HORIZON.as_ns());
        let period = SimTime::from_secs(1);
        c.subs.get_mut(&root).unwrap().telemetry = Some((period, hs));
        c.pump_events();
        let events = c.take_events(root, usize::MAX);
        assert_eq!(events.len(), 120, "{events:?}");
        assert!(
            events.iter().all(|e| matches!(e, Event::Telemetry { .. })),
            "no Lagged may fire for a cursor exactly on the horizon"
        );
        assert!(
            matches!(events[0], Event::Telemetry { from, .. } if from == hs),
            "the first window starts exactly at the horizon"
        );
        // one nanosecond behind: exactly one window is unintegrable —
        // it is skipped, reported, and the cursor rounds up past the
        // horizon (never onto a second phantom miss)
        c.subs.get_mut(&root).unwrap().telemetry = Some((period, SimTime(hs.as_ns() - 1)));
        c.pump_events();
        let events = c.take_events(root, usize::MAX);
        let Event::Lagged { missed } = events[0] else {
            panic!("expected a leading Lagged, got {:?}", events[0]);
        };
        assert_eq!(missed, 1);
        assert_eq!(events.len(), 1 + 119, "{}", events.len());
        assert!(events[1..]
            .iter()
            .all(|e| matches!(e, Event::Telemetry { .. })));
    }

    #[test]
    fn session_expiry_releases_allocation_like_logout() {
        let mut c = cluster();
        c.add_user("alice");
        let sid = c.login("alice").unwrap();
        // a 20-day interactive reservation: still live when the 7-day
        // session TTL lapses
        let (_t, id) = c
            .alloc_ticket(sid, &simple_req("iml-ia770", 1, 20 * 24 * 3600), SimTime::ZERO)
            .unwrap();
        c.wait_alloc(sid, id).unwrap();
        // idle past the sliding TTL (7 days), via the operator console
        let root = c.login("root").unwrap();
        c.handle(
            Some(root),
            &Request::Advance {
                to: SimTime::from_hours(8 * 24),
                sample: false,
            },
        )
        .unwrap();
        // the advance's expiry sweep tore the session down — the
        // allocation is released even though the client never returned
        assert_eq!(c.slurm().job(id).unwrap().state, JobState::Cancelled);
        assert!(matches!(
            c.handle(Some(sid), &Request::ClusterReport),
            Err(DalekError::InvalidSession)
        ));
    }
}
