//! The typed request/response protocol and its JSON wire codec.
//!
//! One envelope shape for every operation the cluster exposes (§3.4–3.5
//! job control, §4.3 energy platform, subscriptions, coordinator
//! reports):
//!
//! ```text
//! {"v": 2, "op": "submit_job", "session": 3, "partition": "az4-n4090", ...}
//! ```
//!
//! [`Request::from_json`] decodes an envelope into `(Option<SessionId>,
//! Request)`; every request except `login` must carry a session token.
//! [`Response::to_json`] encodes the reply. Times travel as seconds
//! (`*_s` fields); job ids and session ids as integers. The codec is
//! built on [`crate::util::json`] and round-trips its grammar, so any
//! JSON-speaking client can drive the cluster — this is the seam where
//! a real network transport plugs in.
//!
//! ## Versioning
//!
//! The envelope carries a major protocol version in `"v"`
//! ([`WIRE_MAJOR`], currently 2: the streaming redesign — nonblocking
//! `run_job`/`alloc_nodes` tickets, subscriptions). The codec is
//! tolerant by construction: unknown fields are ignored (so minor
//! additions never break an older server), an absent `"v"` is accepted
//! as a pre-versioned v1 client, and only a *future major* — a client
//! speaking a grammar this server cannot honour — is refused at decode
//! time with a `BadRequest`.
//!
//! Wire contract for integers: JSON numbers travel as f64, so integer
//! fields are exact only below 2^53. Fields where rounding would lie
//! (`nodes`, `iters`, `job`, `line`, `probe`, `decimate`, `session`)
//! are range-checked and rejected beyond their type's or the wire's
//! range; `seed` (an RNG seed, where precision is inconsequential) is
//! accepted as-is.

use super::error::DalekError;
use super::events::{Channel, Event};
use super::session::SessionId;
use crate::app::{AppSpec, Collective, PhaseSpec};
use crate::energy::Sample;
use crate::faults::FaultKind;
use crate::sim::SimTime;
use crate::slurm::{JobId, JobState};
use crate::util::json::Json;

/// The protocol's major version, carried as `"v"` on every envelope.
/// Version 2 is the streaming redesign: `run_job`/`alloc_nodes` return
/// tickets, `subscribe`/`unsubscribe`/`poll_events` deliver typed
/// events, and the blocking semantics moved to `wait_job`/`wait_alloc`.
pub const WIRE_MAJOR: u64 = 2;

/// What a job submission carries on the wire. The owning user comes
/// from the session; `user` is the admin-only "submit on behalf of"
/// override (sbatch `--uid` style).
#[derive(Clone, Debug, PartialEq)]
pub struct JobRequest {
    pub partition: String,
    pub nodes: u32,
    pub duration: SimTime,
    /// defaults to `4 × duration + 60 s` (the [`crate::slurm::JobSpec`]
    /// helper convention) when absent
    pub time_limit: Option<SimTime>,
    /// AOT payload name; payload jobs execute the real artifact once
    pub payload: Option<String>,
    /// simulated iterations for payload jobs
    pub iters: u64,
    pub user: Option<String>,
    /// phase-structured program (`dalek::app`): `"app": {"phases":
    /// [{"compute_s": 30}, {"collective": "allreduce", "bytes": ...}],
    /// "iterations": 8}`. Mutually exclusive with `payload`; the job's
    /// work ledger is derived from the program, so `duration_s` is
    /// optional
    pub app: Option<AppSpec>,
}

/// Every operation a user can request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Login { user: String },
    Logout,
    AddUser { user: String, admin: bool },
    SubmitJob(JobRequest),
    RunJob(JobRequest),
    AllocNodes(JobRequest),
    JobInfo { job: JobId },
    CancelJob { job: JobId },
    QuerySamples {
        node: String,
        probe: u8,
        from: SimTime,
        to: SimTime,
        decimate: u32,
    },
    QueryEnergy {
        node: Option<String>,
        window: Option<(SimTime, SimTime)>,
    },
    SetTag { node: String, line: u8, high: bool },
    Power { node: String, on: bool },
    ClusterReport,
    Advance { to: SimTime, sample: bool },
    ExecPayload { payload: String, iters: u32, seed: u64 },
    /// Set (or clear, when absent) the §3.6 cluster power budget that
    /// arms the power-cap governor. Admin-only; replies `PowerReport`.
    SetPowerBudget { watts: Option<f64> },
    /// Select a partition's §6.2 placement policy
    /// (`first_fit` | `energy_efficient`). Admin-only.
    SetPolicy { partition: String, policy: String },
    /// Read the governor's telemetry/actuation state.
    PowerReport,
    /// One-shot DQL evaluation (`dalek::query`): a path expression
    /// with wildcards/predicates/aggregation over the virtual cluster
    /// tree, owner-scoped through the session. Replies `QueryResult`.
    Query { expr: String },
    /// Open a typed event channel on this session. `PowerEvents` is
    /// admin-only (it exposes the governor's actuation plane);
    /// `Telemetry` takes a client-chosen decimation rate (`rate_hz`,
    /// default 1 Hz, period at most the 120 s rolling horizon);
    /// `QueryEvents` requires a DQL `expr` to stand up (re-evaluated
    /// on the `rate_hz` cadence, or on job/power edges when absent).
    Subscribe {
        channel: Channel,
        rate_hz: Option<f64>,
        expr: Option<String>,
    },
    /// Close one channel (idempotent; buffered events stay pollable).
    Unsubscribe { channel: Channel },
    /// Drain up to `max` buffered events from this session's outbox; a
    /// pending overflow signal arrives first as a `lagged` event.
    PollEvents { max: u32 },
    /// The thin client-side wait that rebuilds blocking `srun` on top
    /// of a `run_job` ticket: drive the cluster until the job is
    /// terminal. Non-admins may wait only on their own jobs and are
    /// bounded by the srun horizon, exactly like the old blocking op.
    WaitJob { job: JobId },
    /// The blocking half of `alloc_nodes`: drive the cluster until the
    /// allocation exists, grant interactive SSH, return the node names.
    WaitAlloc { job: JobId },
    /// Override a user's per-drain request budget on the multiplexing
    /// `ApiServer` (admin-only; a no-op outside a server).
    SetRateLimit { user: String, ops: u32 },
    /// Configure a user's fair-share weight (admin-only). The first
    /// non-zero share flips the scheduler to priority order with
    /// preemption armed; zeroing every share restores the legacy
    /// submission order bit-identically.
    SetShares { user: String, share: f64 },
    /// Inject one `dalek::faults` anomaly on a node right now, for
    /// `duration` (admin-only). Kind-specific knobs travel as
    /// `floor_w` / `factor` / `fraction`; crash and hang carry none.
    /// Bulk seeded plans go through the operator surface
    /// (`ClusterApi::install_fault_plan`), not the wire.
    InjectFault {
        node: String,
        kind: FaultKind,
        duration: SimTime,
    },
}

/// A job snapshot on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct JobView {
    pub job: JobId,
    pub user: String,
    pub partition: String,
    pub state: JobState,
    pub nodes: u32,
    pub submitted: SimTime,
    pub started: Option<SimTime>,
    pub finished: Option<SimTime>,
}

/// Every reply the protocol can produce.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Session { id: SessionId, user: String, admin: bool },
    LoggedOut,
    UserAdded { user: String },
    Submitted { job: JobId },
    JobRan { job: JobId, state: JobState },
    Allocated { job: JobId, nodes: Vec<String> },
    Job(JobView),
    Cancelled { job: JobId },
    Samples {
        node: String,
        probe: u8,
        /// samples in the window before decimation
        total: u64,
        samples: Vec<Sample>,
    },
    Energy { joules: f64 },
    TagSet { node: String, line: u8, high: bool },
    PowerQueued { node: String, on: bool },
    Report {
        now: SimTime,
        jobs_completed: u64,
        jobs_pending: usize,
        cluster_watts: f64,
        true_energy_j: f64,
        measured_energy_j: f64,
        samples: u64,
    },
    Advanced { now: SimTime },
    Executed {
        payload: String,
        wall_s: f64,
        flops: u64,
        flops_per_sec: f64,
        output_sum: f64,
    },
    /// Governor state: budget, measured rolling watts over the
    /// telemetry window, instantaneous truth, and actuation counters.
    PowerReport {
        budget_w: Option<f64>,
        rolling_w: f64,
        window_s: f64,
        cluster_w: f64,
        throttle: f64,
        capped_nodes: u32,
        governor_ticks: u64,
        idle_shutdowns: u64,
    },
    PolicySet { partition: String, policy: String },
    /// Nonblocking acceptance of `run_job`/`alloc_nodes`: the job is
    /// queued; progress arrives on the `JobEvents` channel (or via
    /// `wait_job`/`wait_alloc`).
    Ticket { ticket: u64, job: JobId },
    Subscribed { channel: Channel },
    Unsubscribed { channel: Channel },
    Events { events: Vec<Event> },
    RateLimitSet { user: String, ops: u32 },
    SharesSet { user: String, share: f64 },
    /// Acknowledges an immediate fault injection (`inject_fault`).
    FaultInjected { node: String, kind: String },
    /// A DQL evaluation: the canonical expression spelling plus the
    /// typed scalar/vector/table result.
    QueryResult {
        expr: String,
        result: crate::query::QueryOutput,
    },
    Error { message: String },
}

pub fn job_state_str(s: JobState) -> &'static str {
    match s {
        JobState::Pending => "pending",
        JobState::Configuring => "configuring",
        JobState::Running => "running",
        JobState::Completed => "completed",
        JobState::Timeout => "timeout",
        JobState::Cancelled => "cancelled",
    }
}

// ---------------------------------------------------------------------------
// decode helpers
// ---------------------------------------------------------------------------

fn bad(msg: impl Into<String>) -> DalekError {
    DalekError::BadRequest(msg.into())
}

fn need_str(o: &Json, k: &str) -> Result<String, DalekError> {
    o.get(k)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| bad(format!("missing string field `{k}`")))
}

fn need_u64(o: &Json, k: &str) -> Result<u64, DalekError> {
    o.get(k)
        .and_then(Json::as_u64)
        .ok_or_else(|| bad(format!("missing integer field `{k}`")))
}

/// Wire numbers travel as f64, whose exact-integer range ends at 2^53;
/// a larger value may already have been rounded by the JSON text, so it
/// is rejected rather than silently accepted.
const SAFE_INT_MAX: u64 = 1 << 53;

fn safe_u64(o: &Json, k: &str, default: u64) -> Result<u64, DalekError> {
    match o.get(k).and_then(Json::as_u64) {
        None => Ok(default),
        Some(v) if v < SAFE_INT_MAX => Ok(v),
        Some(v) => Err(bad(format!(
            "field `{k}` = {v} exceeds the exact integer range of the wire format"
        ))),
    }
}

fn need_safe_u64(o: &Json, k: &str) -> Result<u64, DalekError> {
    let v = need_u64(o, k)?;
    if v >= SAFE_INT_MAX {
        return Err(bad(format!(
            "field `{k}` = {v} exceeds the exact integer range of the wire format"
        )));
    }
    Ok(v)
}

/// Range-checked narrowing — wire integers must never truncate
/// (`nodes: 2^32+1` silently becoming 1 node would be a lie, not an
/// error).
fn narrow<T: TryFrom<u64>>(v: u64, k: &str) -> Result<T, DalekError> {
    T::try_from(v).map_err(|_| bad(format!("field `{k}` out of range: {v}")))
}

fn need_u32(o: &Json, k: &str) -> Result<u32, DalekError> {
    narrow(need_u64(o, k)?, k)
}

fn need_u8(o: &Json, k: &str) -> Result<u8, DalekError> {
    narrow(need_u64(o, k)?, k)
}

fn opt_narrow<T: TryFrom<u64>>(o: &Json, k: &str, default: T) -> Result<T, DalekError> {
    match o.get(k).and_then(Json::as_u64) {
        Some(v) => narrow(v, k),
        None => Ok(default),
    }
}

fn need_f64(o: &Json, k: &str) -> Result<f64, DalekError> {
    o.get(k)
        .and_then(Json::as_f64)
        .ok_or_else(|| bad(format!("missing number field `{k}`")))
}

fn need_bool(o: &Json, k: &str) -> Result<bool, DalekError> {
    o.get(k)
        .and_then(Json::as_bool)
        .ok_or_else(|| bad(format!("missing boolean field `{k}`")))
}

fn opt_bool(o: &Json, k: &str, default: bool) -> bool {
    o.get(k).and_then(Json::as_bool).unwrap_or(default)
}

fn secs(v: f64) -> Result<SimTime, DalekError> {
    if !v.is_finite() || v < 0.0 {
        return Err(bad(format!("time {v} must be a non-negative number")));
    }
    Ok(SimTime::from_secs_f64(v))
}

/// Decode one `{"collective": ..., "bytes": ...}` phase object.
fn collective(o: &Json) -> Result<Collective, DalekError> {
    let kind = need_str(o, "collective")?;
    let bytes = need_safe_u64(o, "bytes")?;
    Ok(match kind.as_str() {
        "bcast" => Collective::Bcast {
            root: opt_narrow(o, "root", 0u32)?,
            bytes,
        },
        "allreduce" => Collective::Allreduce { bytes },
        "alltoall" => Collective::AllToAll { bytes },
        "halo" => Collective::Halo { bytes },
        "p2p" => Collective::PointToPoint {
            from: need_u32(o, "from")?,
            to: need_u32(o, "to")?,
            bytes,
        },
        "nfs_pull" => Collective::NfsPull { bytes },
        other => {
            return Err(bad(format!(
                "unknown collective `{other}` \
                 (bcast | allreduce | alltoall | halo | p2p | nfs_pull)"
            )))
        }
    })
}

/// Decode an `"app"` program object.
fn app_spec(o: &Json) -> Result<AppSpec, DalekError> {
    let phases_json = o
        .get("phases")
        .and_then(Json::as_arr)
        .ok_or_else(|| bad("app needs a `phases` array"))?;
    let mut phases = Vec::with_capacity(phases_json.len());
    for p in phases_json {
        if let Some(w) = p.get("compute_s").and_then(Json::as_f64) {
            if !w.is_finite() || w < 0.0 {
                return Err(bad(format!("`compute_s` = {w} must be finite and >= 0")));
            }
            phases.push(PhaseSpec::Compute { work_s: w });
        } else {
            phases.push(PhaseSpec::Collective(collective(p)?));
        }
    }
    Ok(AppSpec {
        name: o
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("app")
            .to_string(),
        phases,
        iterations: opt_narrow(o, "iterations", 1u32)?,
    })
}

fn job_request(o: &Json) -> Result<JobRequest, DalekError> {
    let payload = o.get("payload").and_then(Json::as_str).map(str::to_string);
    let app = match o.get("app") {
        None | Some(Json::Null) => None,
        Some(a) => Some(app_spec(a)?),
    };
    // payload jobs are sized from the artifact grounding and app jobs
    // from their program, so their duration is optional on the wire;
    // synthetic jobs must state one
    let duration = match o.get("duration_s").and_then(Json::as_f64) {
        Some(v) => secs(v)?,
        None if payload.is_some() || app.is_some() => SimTime::ZERO,
        None => return Err(bad("missing number field `duration_s`")),
    };
    Ok(JobRequest {
        partition: need_str(o, "partition")?,
        nodes: need_u32(o, "nodes")?,
        duration,
        time_limit: match o.get("time_limit_s").and_then(Json::as_f64) {
            Some(v) => Some(secs(v)?),
            None => None,
        },
        payload,
        iters: safe_u64(o, "iters", 1)?,
        user: o.get("user").and_then(Json::as_str).map(str::to_string),
        app,
    })
}

impl Request {
    /// Decode one wire envelope. Unknown fields are tolerated (minor
    /// additions must not break this server); a future-major `"v"` is
    /// refused — the client speaks a grammar we cannot honour.
    pub fn from_json(j: &Json) -> Result<(Option<SessionId>, Request), DalekError> {
        match j.get("v") {
            None => {} // pre-versioned v1 client
            Some(v) => {
                let major = v.as_u64().ok_or_else(|| {
                    bad(format!(
                        "field `v` must be a non-negative integer protocol version, got {v}"
                    ))
                })?;
                if major > WIRE_MAJOR {
                    return Err(bad(format!(
                        "protocol version {major} is newer than this server speaks \
                         (max {WIRE_MAJOR})"
                    )));
                }
            }
        }
        let op = need_str(j, "op")?;
        let session = match j.get("session").and_then(Json::as_u64) {
            None => None,
            Some(v) if v < SAFE_INT_MAX => Some(SessionId(v)),
            Some(v) => {
                return Err(bad(format!(
                    "field `session` = {v} exceeds the exact integer range of the wire format"
                )))
            }
        };
        let req = match op.as_str() {
            "login" => Request::Login {
                user: need_str(j, "user")?,
            },
            "logout" => Request::Logout,
            "add_user" => Request::AddUser {
                user: need_str(j, "user")?,
                admin: opt_bool(j, "admin", false),
            },
            "submit_job" => Request::SubmitJob(job_request(j)?),
            "run_job" => Request::RunJob(job_request(j)?),
            "alloc_nodes" => Request::AllocNodes(job_request(j)?),
            "job_info" => Request::JobInfo {
                job: JobId(need_safe_u64(j, "job")?),
            },
            "cancel_job" => Request::CancelJob {
                job: JobId(need_safe_u64(j, "job")?),
            },
            "query_samples" => Request::QuerySamples {
                node: need_str(j, "node")?,
                probe: opt_narrow(j, "probe", 0u8)?,
                from: secs(need_f64(j, "from_s")?)?,
                to: secs(need_f64(j, "to_s")?)?,
                decimate: opt_narrow(j, "decimate", 1u32)?,
            },
            "query_energy" => {
                let from = j.get("from_s").and_then(Json::as_f64);
                let to = j.get("to_s").and_then(Json::as_f64);
                let window = match (from, to) {
                    (Some(a), Some(b)) => Some((secs(a)?, secs(b)?)),
                    (None, None) => None,
                    _ => return Err(bad("`from_s` and `to_s` must come together")),
                };
                Request::QueryEnergy {
                    node: j.get("node").and_then(Json::as_str).map(str::to_string),
                    window,
                }
            }
            "set_tag" => Request::SetTag {
                node: need_str(j, "node")?,
                line: need_u8(j, "line")?,
                high: need_bool(j, "high")?,
            },
            "power" => Request::Power {
                node: need_str(j, "node")?,
                on: need_bool(j, "on")?,
            },
            "cluster_report" => Request::ClusterReport,
            "advance" => Request::Advance {
                to: secs(need_f64(j, "to_s")?)?,
                sample: opt_bool(j, "sample", false),
            },
            "exec_payload" => Request::ExecPayload {
                payload: need_str(j, "payload")?,
                iters: opt_narrow(j, "iters", 1u32)?,
                // seed is an RNG seed: wire rounding above 2^53 is
                // harmless, so it is not range-checked (see module doc)
                seed: j.get("seed").and_then(Json::as_u64).unwrap_or(42),
            },
            "set_power_budget" => {
                // absent or null clears the budget; anything else must
                // be a positive number (a mistyped string must not
                // silently disarm the governor)
                let watts = match j.get("watts") {
                    None | Some(Json::Null) => None,
                    Some(v) => match v.as_f64() {
                        Some(w) if w.is_finite() && w > 0.0 => Some(w),
                        _ => {
                            return Err(bad(format!(
                                "field `watts` must be a positive number of watts, got {v}"
                            )))
                        }
                    },
                };
                Request::SetPowerBudget { watts }
            }
            "set_policy" => {
                let policy = need_str(j, "policy")?;
                if crate::slurm::PlacementPolicy::from_wire(&policy).is_none() {
                    return Err(bad(format!(
                        "unknown policy `{policy}` (first_fit | energy_efficient)"
                    )));
                }
                Request::SetPolicy {
                    partition: need_str(j, "partition")?,
                    policy,
                }
            }
            "power_report" => Request::PowerReport,
            "query" => Request::Query {
                expr: need_str(j, "expr")?,
            },
            "subscribe" => {
                let ch = need_str(j, "channel")?;
                let channel = Channel::from_wire(&ch).ok_or_else(|| {
                    bad(format!(
                        "unknown channel `{ch}` \
                         (job_events | power_events | fault_events | telemetry | query_events)"
                    ))
                })?;
                let rate_hz = match j.get("rate_hz") {
                    None | Some(Json::Null) => None,
                    Some(v) => match v.as_f64() {
                        Some(r) if r.is_finite() && r > 0.0 => Some(r),
                        _ => {
                            return Err(bad(format!(
                                "field `rate_hz` must be a positive number, got {v}"
                            )))
                        }
                    },
                };
                let expr = match j.get("expr") {
                    None | Some(Json::Null) => None,
                    Some(v) => match v.as_str() {
                        Some(s) => Some(s.to_string()),
                        None => {
                            return Err(bad(format!(
                                "field `expr` must be a string, got {v}"
                            )))
                        }
                    },
                };
                Request::Subscribe {
                    channel,
                    rate_hz,
                    expr,
                }
            }
            "unsubscribe" => {
                let ch = need_str(j, "channel")?;
                let channel = Channel::from_wire(&ch).ok_or_else(|| {
                    bad(format!(
                        "unknown channel `{ch}` \
                         (job_events | power_events | fault_events | telemetry | query_events)"
                    ))
                })?;
                Request::Unsubscribe { channel }
            }
            "poll_events" => Request::PollEvents {
                max: opt_narrow(j, "max", 64u32)?,
            },
            "wait_job" => Request::WaitJob {
                job: JobId(need_safe_u64(j, "job")?),
            },
            "wait_alloc" => Request::WaitAlloc {
                job: JobId(need_safe_u64(j, "job")?),
            },
            "set_rate_limit" => {
                let ops = need_u32(j, "ops")?;
                if ops == 0 {
                    // 0 would wedge the client's queue forever; the
                    // server clamps defensively, but the wire must not
                    // acknowledge a limit that is not applied
                    return Err(bad("field `ops` must be at least 1"));
                }
                Request::SetRateLimit {
                    user: need_str(j, "user")?,
                    ops,
                }
            }
            "set_shares" => {
                let share = need_f64(j, "share")?;
                if !share.is_finite() || share < 0.0 {
                    return Err(bad(format!(
                        "field `share` must be a finite non-negative weight, got {share}"
                    )));
                }
                Request::SetShares {
                    user: need_str(j, "user")?,
                    share,
                }
            }
            "inject_fault" => {
                let kind_s = need_str(j, "kind")?;
                let ratio = |key: &str| -> Result<f64, DalekError> {
                    let v = need_f64(j, key)?;
                    if !v.is_finite() || v <= 0.0 || v > 1.0 {
                        return Err(bad(format!("field `{key}` must be in (0, 1], got {v}")));
                    }
                    Ok(v)
                };
                let kind = match kind_s.as_str() {
                    "crash" => FaultKind::Crash,
                    "hang" => FaultKind::Hang,
                    "brownout" => {
                        let floor_w = need_f64(j, "floor_w")?;
                        if !floor_w.is_finite() || floor_w <= 0.0 {
                            return Err(bad(format!(
                                "field `floor_w` must be a positive number of watts, \
                                 got {floor_w}"
                            )));
                        }
                        FaultKind::Brownout { floor_w }
                    }
                    "throttle" => FaultKind::Throttle {
                        factor: ratio("factor")?,
                    },
                    "link_degrade" => FaultKind::LinkDegrade {
                        fraction: ratio("fraction")?,
                    },
                    other => {
                        return Err(bad(format!(
                            "unknown fault kind `{other}` \
                             (crash | hang | brownout | throttle | link_degrade)"
                        )))
                    }
                };
                let duration = secs(need_f64(j, "duration_s")?)?;
                if duration == SimTime::ZERO {
                    return Err(bad("field `duration_s` must be positive"));
                }
                Request::InjectFault {
                    node: need_str(j, "node")?,
                    kind,
                    duration,
                }
            }
            other => return Err(bad(format!("unknown op `{other}`"))),
        };
        Ok((session, req))
    }

    /// Decode from source text.
    pub fn parse(src: &str) -> Result<(Option<SessionId>, Request), DalekError> {
        Request::from_json(&Json::parse(src)?)
    }

    /// Encode one wire envelope.
    pub fn to_json(&self, session: Option<SessionId>) -> Json {
        let mut fields: Vec<(String, Json)> = Vec::new();
        let mut push = |k: &str, v: Json| fields.push((k.to_string(), v));
        let job_fields = |push: &mut dyn FnMut(&str, Json), r: &JobRequest| {
            push("partition", Json::from(r.partition.as_str()));
            push("nodes", Json::from(r.nodes));
            push("duration_s", Json::from(r.duration.as_secs_f64()));
            if let Some(tl) = r.time_limit {
                push("time_limit_s", Json::from(tl.as_secs_f64()));
            }
            if let Some(p) = &r.payload {
                push("payload", Json::from(p.as_str()));
            }
            if r.iters != 1 {
                push("iters", Json::from(r.iters));
            }
            if let Some(u) = &r.user {
                push("user", Json::from(u.as_str()));
            }
            if let Some(a) = &r.app {
                push("app", app_json(a));
            }
        };
        let op = match self {
            Request::Login { user } => {
                push("user", Json::from(user.as_str()));
                "login"
            }
            Request::Logout => "logout",
            Request::AddUser { user, admin } => {
                push("user", Json::from(user.as_str()));
                push("admin", Json::from(*admin));
                "add_user"
            }
            Request::SubmitJob(r) => {
                job_fields(&mut push, r);
                "submit_job"
            }
            Request::RunJob(r) => {
                job_fields(&mut push, r);
                "run_job"
            }
            Request::AllocNodes(r) => {
                job_fields(&mut push, r);
                "alloc_nodes"
            }
            Request::JobInfo { job } => {
                push("job", Json::from(job.0));
                "job_info"
            }
            Request::CancelJob { job } => {
                push("job", Json::from(job.0));
                "cancel_job"
            }
            Request::QuerySamples {
                node,
                probe,
                from,
                to,
                decimate,
            } => {
                push("node", Json::from(node.as_str()));
                push("probe", Json::from(*probe));
                push("from_s", Json::from(from.as_secs_f64()));
                push("to_s", Json::from(to.as_secs_f64()));
                push("decimate", Json::from(*decimate));
                "query_samples"
            }
            Request::QueryEnergy { node, window } => {
                if let Some(n) = node {
                    push("node", Json::from(n.as_str()));
                }
                if let Some((a, b)) = window {
                    push("from_s", Json::from(a.as_secs_f64()));
                    push("to_s", Json::from(b.as_secs_f64()));
                }
                "query_energy"
            }
            Request::SetTag { node, line, high } => {
                push("node", Json::from(node.as_str()));
                push("line", Json::from(*line));
                push("high", Json::from(*high));
                "set_tag"
            }
            Request::Power { node, on } => {
                push("node", Json::from(node.as_str()));
                push("on", Json::from(*on));
                "power"
            }
            Request::ClusterReport => "cluster_report",
            Request::Advance { to, sample } => {
                push("to_s", Json::from(to.as_secs_f64()));
                push("sample", Json::from(*sample));
                "advance"
            }
            Request::ExecPayload {
                payload,
                iters,
                seed,
            } => {
                push("payload", Json::from(payload.as_str()));
                push("iters", Json::from(*iters));
                push("seed", Json::from(*seed));
                "exec_payload"
            }
            Request::SetPowerBudget { watts } => {
                if let Some(w) = watts {
                    push("watts", Json::from(*w));
                }
                "set_power_budget"
            }
            Request::SetPolicy { partition, policy } => {
                push("partition", Json::from(partition.as_str()));
                push("policy", Json::from(policy.as_str()));
                "set_policy"
            }
            Request::PowerReport => "power_report",
            Request::Query { expr } => {
                push("expr", Json::from(expr.as_str()));
                "query"
            }
            Request::Subscribe {
                channel,
                rate_hz,
                expr,
            } => {
                push("channel", Json::from(channel.as_str()));
                if let Some(r) = rate_hz {
                    push("rate_hz", Json::from(*r));
                }
                if let Some(e) = expr {
                    push("expr", Json::from(e.as_str()));
                }
                "subscribe"
            }
            Request::Unsubscribe { channel } => {
                push("channel", Json::from(channel.as_str()));
                "unsubscribe"
            }
            Request::PollEvents { max } => {
                push("max", Json::from(*max));
                "poll_events"
            }
            Request::WaitJob { job } => {
                push("job", Json::from(job.0));
                "wait_job"
            }
            Request::WaitAlloc { job } => {
                push("job", Json::from(job.0));
                "wait_alloc"
            }
            Request::SetRateLimit { user, ops } => {
                push("user", Json::from(user.as_str()));
                push("ops", Json::from(*ops));
                "set_rate_limit"
            }
            Request::SetShares { user, share } => {
                push("user", Json::from(user.as_str()));
                push("share", Json::from(*share));
                "set_shares"
            }
            Request::InjectFault {
                node,
                kind,
                duration,
            } => {
                push("node", Json::from(node.as_str()));
                push("kind", Json::from(kind.label()));
                match *kind {
                    FaultKind::Brownout { floor_w } => push("floor_w", Json::from(floor_w)),
                    FaultKind::Throttle { factor } => push("factor", Json::from(factor)),
                    FaultKind::LinkDegrade { fraction } => {
                        push("fraction", Json::from(fraction))
                    }
                    FaultKind::Crash | FaultKind::Hang => {}
                }
                push("duration_s", Json::from(duration.as_secs_f64()));
                "inject_fault"
            }
        };
        fields.push(("op".to_string(), Json::from(op)));
        fields.push(("v".to_string(), Json::from(WIRE_MAJOR)));
        if let Some(s) = session {
            fields.push(("session".to_string(), Json::from(s.0)));
        }
        Json::object(fields)
    }
}

/// Encode an app program as its wire object.
fn app_json(a: &AppSpec) -> Json {
    let phases = a.phases.iter().map(|p| match p {
        PhaseSpec::Compute { work_s } => Json::object([("compute_s", Json::from(*work_s))]),
        PhaseSpec::Collective(c) => {
            let mut fields: Vec<(&str, Json)> = vec![("collective", Json::from(c.name()))];
            match *c {
                Collective::Bcast { root, bytes } => {
                    fields.push(("root", Json::from(root)));
                    fields.push(("bytes", Json::from(bytes)));
                }
                Collective::Allreduce { bytes }
                | Collective::AllToAll { bytes }
                | Collective::Halo { bytes }
                | Collective::NfsPull { bytes } => fields.push(("bytes", Json::from(bytes))),
                Collective::PointToPoint { from, to, bytes } => {
                    fields.push(("from", Json::from(from)));
                    fields.push(("to", Json::from(to)));
                    fields.push(("bytes", Json::from(bytes)));
                }
            }
            Json::object(fields)
        }
    });
    Json::object([
        ("name", Json::from(a.name.as_str())),
        ("iterations", Json::from(a.iterations)),
        ("phases", Json::array(phases)),
    ])
}

fn sample_json(s: &Sample) -> Json {
    Json::object([
        ("t_s", Json::from(s.t.as_secs_f64())),
        ("power_w", Json::from(s.power_w)),
        ("voltage_v", Json::from(s.voltage_v)),
        ("current_a", Json::from(s.current_a)),
        ("tags", Json::from(s.tags)),
    ])
}

impl Response {
    /// Encode a reply. Every success carries `"ok": true` plus a
    /// `"type"` discriminant; errors carry `"ok": false` + `"error"`.
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(String, Json)> = Vec::new();
        let mut push = |k: &str, v: Json| fields.push((k.to_string(), v));
        let ty = match self {
            Response::Session { id, user, admin } => {
                push("session", Json::from(id.0));
                push("user", Json::from(user.as_str()));
                push("admin", Json::from(*admin));
                "session"
            }
            Response::LoggedOut => "logged_out",
            Response::UserAdded { user } => {
                push("user", Json::from(user.as_str()));
                "user_added"
            }
            Response::Submitted { job } => {
                push("job", Json::from(job.0));
                "submitted"
            }
            Response::JobRan { job, state } => {
                push("job", Json::from(job.0));
                push("state", Json::from(job_state_str(*state)));
                "job_ran"
            }
            Response::Allocated { job, nodes } => {
                push("job", Json::from(job.0));
                push(
                    "nodes",
                    Json::array(nodes.iter().map(|n| Json::from(n.as_str()))),
                );
                "allocated"
            }
            Response::Job(v) => {
                push("job", Json::from(v.job.0));
                push("user", Json::from(v.user.as_str()));
                push("partition", Json::from(v.partition.as_str()));
                push("state", Json::from(job_state_str(v.state)));
                push("nodes", Json::from(v.nodes));
                push("submitted_s", Json::from(v.submitted.as_secs_f64()));
                if let Some(t) = v.started {
                    push("started_s", Json::from(t.as_secs_f64()));
                }
                if let Some(t) = v.finished {
                    push("finished_s", Json::from(t.as_secs_f64()));
                }
                "job"
            }
            Response::Cancelled { job } => {
                push("job", Json::from(job.0));
                "cancelled"
            }
            Response::Samples {
                node,
                probe,
                total,
                samples,
            } => {
                push("node", Json::from(node.as_str()));
                push("probe", Json::from(*probe));
                push("total", Json::from(*total));
                push("samples", Json::array(samples.iter().map(sample_json)));
                "samples"
            }
            Response::Energy { joules } => {
                push("joules", Json::from(*joules));
                "energy"
            }
            Response::TagSet { node, line, high } => {
                push("node", Json::from(node.as_str()));
                push("line", Json::from(*line));
                push("high", Json::from(*high));
                "tag_set"
            }
            Response::PowerQueued { node, on } => {
                push("node", Json::from(node.as_str()));
                push("on", Json::from(*on));
                "power_queued"
            }
            Response::Report {
                now,
                jobs_completed,
                jobs_pending,
                cluster_watts,
                true_energy_j,
                measured_energy_j,
                samples,
            } => {
                push("now_s", Json::from(now.as_secs_f64()));
                push("jobs_completed", Json::from(*jobs_completed));
                push("jobs_pending", Json::from(*jobs_pending));
                push("cluster_watts", Json::from(*cluster_watts));
                push("true_energy_j", Json::from(*true_energy_j));
                push("measured_energy_j", Json::from(*measured_energy_j));
                push("samples", Json::from(*samples));
                "report"
            }
            Response::Advanced { now } => {
                push("now_s", Json::from(now.as_secs_f64()));
                "advanced"
            }
            Response::Executed {
                payload,
                wall_s,
                flops,
                flops_per_sec,
                output_sum,
            } => {
                push("payload", Json::from(payload.as_str()));
                push("wall_s", Json::from(*wall_s));
                push("flops", Json::from(*flops));
                push("flops_per_sec", Json::from(*flops_per_sec));
                push("output_sum", Json::from(*output_sum));
                "executed"
            }
            Response::PowerReport {
                budget_w,
                rolling_w,
                window_s,
                cluster_w,
                throttle,
                capped_nodes,
                governor_ticks,
                idle_shutdowns,
            } => {
                if let Some(b) = budget_w {
                    push("budget_w", Json::from(*b));
                }
                push("rolling_w", Json::from(*rolling_w));
                push("window_s", Json::from(*window_s));
                push("cluster_w", Json::from(*cluster_w));
                push("throttle", Json::from(*throttle));
                push("capped_nodes", Json::from(*capped_nodes));
                push("governor_ticks", Json::from(*governor_ticks));
                push("idle_shutdowns", Json::from(*idle_shutdowns));
                "power_report"
            }
            Response::PolicySet { partition, policy } => {
                push("partition", Json::from(partition.as_str()));
                push("policy", Json::from(policy.as_str()));
                "policy_set"
            }
            Response::Ticket { ticket, job } => {
                push("ticket", Json::from(*ticket));
                push("job", Json::from(job.0));
                "ticket"
            }
            Response::Subscribed { channel } => {
                push("channel", Json::from(channel.as_str()));
                "subscribed"
            }
            Response::Unsubscribed { channel } => {
                push("channel", Json::from(channel.as_str()));
                "unsubscribed"
            }
            Response::Events { events } => {
                push("events", Json::array(events.iter().map(Event::to_json)));
                push("count", Json::from(events.len()));
                "events"
            }
            Response::RateLimitSet { user, ops } => {
                push("user", Json::from(user.as_str()));
                push("ops", Json::from(*ops));
                "rate_limit_set"
            }
            Response::SharesSet { user, share } => {
                push("user", Json::from(user.as_str()));
                push("share", Json::from(*share));
                "shares_set"
            }
            Response::FaultInjected { node, kind } => {
                push("node", Json::from(node.as_str()));
                push("kind", Json::from(kind.as_str()));
                "fault_injected"
            }
            Response::QueryResult { expr, result } => {
                push("expr", Json::from(expr.as_str()));
                // splice the result's wire object (kind + payload) —
                // the same encoding standing-query events carry
                if let Json::Obj(m) = crate::query::output_json(result) {
                    for (k, v) in m {
                        fields.push((k, v));
                    }
                }
                "query_result"
            }
            Response::Error { message } => {
                let j = Json::object([
                    ("ok", Json::from(false)),
                    ("v", Json::from(WIRE_MAJOR)),
                    ("error", Json::from(message.as_str())),
                ]);
                return j;
            }
        };
        fields.push(("ok".to_string(), Json::from(true)));
        fields.push(("v".to_string(), Json::from(WIRE_MAJOR)));
        fields.push(("type".to_string(), Json::from(ty)));
        Json::object(fields)
    }

    /// Errors encode uniformly; convenience for handlers.
    pub fn from_error(e: &DalekError) -> Response {
        Response::Error {
            message: e.to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn login_needs_no_session_and_round_trips() {
        let req = Request::Login {
            user: "alice".into(),
        };
        let wire = req.to_json(None).to_string();
        let (sid, back) = Request::parse(&wire).unwrap();
        assert_eq!(sid, None);
        assert_eq!(back, req);
    }

    #[test]
    fn submit_round_trips_with_session() {
        let req = Request::SubmitJob(JobRequest {
            partition: "az4-n4090".into(),
            nodes: 2,
            duration: SimTime::from_secs(120),
            time_limit: Some(SimTime::from_mins(30)),
            payload: Some("gemm256".into()),
            iters: 50_000,
            user: None,
            app: None,
        });
        let wire = req.to_json(Some(SessionId(7))).to_string();
        let (sid, back) = Request::parse(&wire).unwrap();
        assert_eq!(sid, Some(SessionId(7)));
        assert_eq!(back, req);
    }

    #[test]
    fn every_op_round_trips() {
        let reqs = vec![
            Request::Logout,
            Request::AddUser {
                user: "bob".into(),
                admin: true,
            },
            Request::RunJob(JobRequest {
                partition: "az5-a890m".into(),
                nodes: 1,
                duration: SimTime::from_secs(30),
                time_limit: None,
                payload: None,
                iters: 1,
                user: Some("carol".into()),
                app: None,
            }),
            Request::AllocNodes(JobRequest {
                partition: "iml-ia770".into(),
                nodes: 2,
                duration: SimTime::from_secs(60),
                time_limit: None,
                payload: None,
                iters: 7, // non-payload iters must round-trip too
                user: None,
                app: None,
            }),
            Request::JobInfo { job: JobId(4) },
            Request::CancelJob { job: JobId(5) },
            Request::QuerySamples {
                node: "az4-n4090-0".into(),
                probe: 0,
                from: SimTime::ZERO,
                to: SimTime::from_secs(10),
                decimate: 100,
            },
            Request::QueryEnergy {
                node: Some("az4-n4090-0".into()),
                window: Some((SimTime::ZERO, SimTime::from_secs(5))),
            },
            Request::QueryEnergy {
                node: None,
                window: None,
            },
            Request::SetTag {
                node: "az4-n4090-0".into(),
                line: 3,
                high: true,
            },
            Request::Power {
                node: "az4-n4090-0".into(),
                on: false,
            },
            Request::ClusterReport,
            Request::Advance {
                to: SimTime::from_hours(1),
                sample: true,
            },
            Request::ExecPayload {
                payload: "mlp_infer".into(),
                iters: 3,
                seed: 42,
            },
            Request::SetPowerBudget {
                watts: Some(1234.5),
            },
            Request::SetPowerBudget { watts: None },
            Request::SetPolicy {
                partition: "az5-a890m".into(),
                policy: "energy_efficient".into(),
            },
            Request::PowerReport,
            Request::Subscribe {
                channel: Channel::JobEvents,
                rate_hz: None,
                expr: None,
            },
            Request::Subscribe {
                channel: Channel::Telemetry,
                rate_hz: Some(10.0),
                expr: None,
            },
            Request::Subscribe {
                channel: Channel::QueryEvents,
                rate_hz: Some(0.5),
                expr: Some("sum(nodes.*.power.watts)".into()),
            },
            Request::Unsubscribe {
                channel: Channel::PowerEvents,
            },
            Request::Query {
                expr: "mean(nodes[partition=\"az5-a890m\"].power.watts, window=60s)".into(),
            },
            Request::PollEvents { max: 32 },
            Request::WaitJob { job: JobId(7) },
            Request::WaitAlloc { job: JobId(8) },
            Request::SetRateLimit {
                user: "alice".into(),
                ops: 2,
            },
            Request::SetShares {
                user: "alice".into(),
                share: 2.5,
            },
            Request::SetShares {
                user: "bob".into(),
                share: 0.0, // zeroing a share must survive the wire too
            },
            Request::InjectFault {
                node: "az4-n4090-0".into(),
                kind: FaultKind::Crash,
                duration: SimTime::from_secs(120),
            },
            Request::InjectFault {
                node: "az5-a890m-1".into(),
                kind: FaultKind::Brownout { floor_w: 150.0 },
                duration: SimTime::from_secs(60),
            },
            Request::InjectFault {
                node: "az4-n4090-1".into(),
                kind: FaultKind::Throttle { factor: 0.5 },
                duration: SimTime::from_secs(300),
            },
            Request::InjectFault {
                node: "az4-n4090-2".into(),
                kind: FaultKind::LinkDegrade { fraction: 0.25 },
                duration: SimTime::from_secs(90),
            },
        ];
        for req in reqs {
            let wire = req.to_json(Some(SessionId(1))).to_string();
            let (sid, back) =
                Request::parse(&wire).unwrap_or_else(|e| panic!("{wire}: {e}"));
            assert_eq!(sid, Some(SessionId(1)), "{wire}");
            assert_eq!(back, req, "{wire}");
        }
    }

    #[test]
    fn app_requests_round_trip_and_validate() {
        // every collective survives the wire
        let app = AppSpec::new(
            "cnn-train",
            vec![
                PhaseSpec::Compute { work_s: 30.0 },
                PhaseSpec::Collective(Collective::Allreduce { bytes: 64_000_000 }),
                PhaseSpec::Collective(Collective::Bcast {
                    root: 1,
                    bytes: 1_000,
                }),
                PhaseSpec::Collective(Collective::AllToAll { bytes: 2_000 }),
                PhaseSpec::Collective(Collective::Halo { bytes: 3_000 }),
                PhaseSpec::Collective(Collective::PointToPoint {
                    from: 0,
                    to: 3,
                    bytes: 4_000,
                }),
                PhaseSpec::Collective(Collective::NfsPull { bytes: 5_000 }),
            ],
            8,
        );
        let req = Request::SubmitJob(JobRequest {
            partition: "az4-n4090".into(),
            nodes: 4,
            duration: SimTime::ZERO,
            time_limit: None,
            payload: None,
            iters: 1,
            user: None,
            app: Some(app),
        });
        let wire = req.to_json(Some(SessionId(3))).to_string();
        let (sid, back) = Request::parse(&wire).unwrap_or_else(|e| panic!("{wire}: {e}"));
        assert_eq!(sid, Some(SessionId(3)));
        assert_eq!(back, req);

        // app jobs need no duration_s; phases are required
        let (_, req) = Request::parse(
            r#"{"op": "submit_job", "session": 1, "partition": "az5-a890m", "nodes": 2,
                "app": {"phases": [{"compute_s": 10},
                                   {"collective": "allreduce", "bytes": 1000}]}}"#,
        )
        .unwrap();
        let Request::SubmitJob(r) = req else {
            panic!("expected SubmitJob")
        };
        let app = r.app.expect("app decoded");
        assert_eq!(app.iterations, 1); // default
        assert_eq!(app.phases.len(), 2);
        assert_eq!(r.duration, SimTime::ZERO);
        assert!(matches!(
            Request::parse(r#"{"op": "submit_job", "partition": "p", "nodes": 2, "app": {}}"#),
            Err(DalekError::BadRequest(_))
        ));
        assert!(matches!(
            Request::parse(
                r#"{"op": "submit_job", "partition": "p", "nodes": 2,
                    "app": {"phases": [{"collective": "warp", "bytes": 1}]}}"#
            ),
            Err(DalekError::BadRequest(_))
        ));
        assert!(matches!(
            Request::parse(
                r#"{"op": "submit_job", "partition": "p", "nodes": 2,
                    "app": {"phases": [{"compute_s": -1}]}}"#
            ),
            Err(DalekError::BadRequest(_))
        ));
    }

    #[test]
    fn versioning_tolerates_past_rejects_future() {
        // the encoder stamps the current major
        let wire = Request::PowerReport.to_json(Some(SessionId(1)));
        assert_eq!(wire.get("v").unwrap().as_u64(), Some(WIRE_MAJOR));
        // absent v = pre-versioned v1 client: accepted
        let (_, r) = Request::parse(r#"{"op": "power_report", "session": 1}"#).unwrap();
        assert_eq!(r, Request::PowerReport);
        // same or older major: accepted
        for v in 1..=WIRE_MAJOR {
            let (_, r) = Request::parse(&format!(
                r#"{{"op": "power_report", "session": 1, "v": {v}}}"#
            ))
            .unwrap();
            assert_eq!(r, Request::PowerReport);
        }
        // a future major is refused at decode time
        let e = Request::parse(r#"{"op": "power_report", "session": 1, "v": 99}"#).unwrap_err();
        assert!(matches!(e, DalekError::BadRequest(_)));
        assert!(e.to_string().contains("99"), "{e}");
        // and a mistyped version is an error, not silently v1
        assert!(matches!(
            Request::parse(r#"{"op": "power_report", "session": 1, "v": "two"}"#),
            Err(DalekError::BadRequest(_))
        ));
        assert!(matches!(
            Request::parse(r#"{"op": "power_report", "session": 1, "v": 1.5}"#),
            Err(DalekError::BadRequest(_))
        ));
    }

    #[test]
    fn prop_codec_tolerates_unknown_fields() {
        // forward tolerance: any request decorated with arbitrary
        // unknown fields must decode to the same typed request (minor
        // protocol additions never break this server)
        use crate::util::Xoshiro256;
        let mut rng = Xoshiro256::new(0x0E1);
        let reqs = vec![
            Request::Login { user: "alice".into() },
            Request::PowerReport,
            Request::PollEvents { max: 5 },
            Request::Subscribe {
                channel: Channel::Telemetry,
                rate_hz: Some(2.0),
                expr: None,
            },
            Request::JobInfo { job: JobId(3) },
            Request::QueryEnergy {
                node: None,
                window: None,
            },
        ];
        for case in 0..100 {
            let req = &reqs[rng.index(reqs.len())];
            let Json::Obj(mut o) = req.to_json(Some(SessionId(1))) else {
                panic!("envelope is an object")
            };
            for k in 0..rng.uniform_u64(1, 4) {
                let key = format!("x_future_field_{case}_{k}");
                let val = match rng.uniform_u64(0, 3) {
                    0 => Json::from(rng.next_f64()),
                    1 => Json::from("text"),
                    2 => Json::array([Json::from(1u64)]),
                    _ => Json::object([("nested", Json::Bool(true))]),
                };
                o.insert(key, val);
            }
            let decorated = Json::Obj(o).to_string();
            let (sid, back) = Request::parse(&decorated)
                .unwrap_or_else(|e| panic!("case {case}: `{decorated}`: {e}"));
            assert_eq!(sid, Some(SessionId(1)), "case {case}");
            assert_eq!(&back, req, "case {case}");
        }
    }

    #[test]
    fn ticket_and_events_encode() {
        let t = Response::Ticket {
            ticket: 9,
            job: JobId(4),
        }
        .to_json();
        assert_eq!(t.get("type").unwrap().as_str(), Some("ticket"));
        assert_eq!(t.get("ticket").unwrap().as_u64(), Some(9));
        assert_eq!(t.get("job").unwrap().as_u64(), Some(4));
        assert_eq!(t.get("v").unwrap().as_u64(), Some(WIRE_MAJOR));
        let e = Response::Events {
            events: vec![Event::Lagged { missed: 3 }],
        }
        .to_json();
        assert_eq!(e.get("count").unwrap().as_u64(), Some(1));
        let arr = e.get("events").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].get("event").unwrap().as_str(), Some("lagged"));
        // bad subscribe channels and rates are rejected at decode
        assert!(matches!(
            Request::parse(r#"{"op": "subscribe", "channel": "davros", "session": 1}"#),
            Err(DalekError::BadRequest(_))
        ));
        assert!(matches!(
            Request::parse(
                r#"{"op": "subscribe", "channel": "telemetry", "rate_hz": -1, "session": 1}"#
            ),
            Err(DalekError::BadRequest(_))
        ));
        // a zero rate limit would wedge the client's queue: refused
        assert!(matches!(
            Request::parse(r#"{"op": "set_rate_limit", "user": "a", "ops": 0, "session": 1}"#),
            Err(DalekError::BadRequest(_))
        ));
        // negative / non-finite fair-share weights are refused at the wire
        assert!(matches!(
            Request::parse(r#"{"op": "set_shares", "user": "a", "share": -1, "session": 1}"#),
            Err(DalekError::BadRequest(_))
        ));
        assert!(matches!(
            Request::parse(r#"{"op": "set_shares", "user": "a", "share": "big", "session": 1}"#),
            Err(DalekError::BadRequest(_))
        ));
        // query needs an expr string; subscribe's expr must be a string
        assert!(matches!(
            Request::parse(r#"{"op": "query", "session": 1}"#),
            Err(DalekError::BadRequest(_))
        ));
        assert!(matches!(
            Request::parse(
                r#"{"op": "subscribe", "channel": "query_events", "expr": 7, "session": 1}"#
            ),
            Err(DalekError::BadRequest(_))
        ));
        // expr = null is treated as absent
        let (_, r) = Request::parse(
            r#"{"op": "subscribe", "channel": "job_events", "expr": null, "session": 1}"#,
        )
        .unwrap();
        assert_eq!(
            r,
            Request::Subscribe {
                channel: Channel::JobEvents,
                rate_hz: None,
                expr: None,
            }
        );
    }

    #[test]
    fn inject_fault_wire_validation() {
        // an unknown fault kind is refused with the menu
        let e = Request::parse(
            r#"{"op": "inject_fault", "node": "n", "kind": "emp", "duration_s": 10, "session": 1}"#,
        )
        .unwrap_err();
        assert!(e.to_string().contains("link_degrade"), "{e}");
        // throttle factor and link fraction are ratios in (0, 1]
        assert!(matches!(
            Request::parse(
                r#"{"op": "inject_fault", "node": "n", "kind": "throttle",
                    "factor": 1.5, "duration_s": 10, "session": 1}"#
            ),
            Err(DalekError::BadRequest(_))
        ));
        assert!(matches!(
            Request::parse(
                r#"{"op": "inject_fault", "node": "n", "kind": "link_degrade",
                    "fraction": 0, "duration_s": 10, "session": 1}"#
            ),
            Err(DalekError::BadRequest(_))
        ));
        // a brownout must state its floor; crash needs no knobs
        assert!(matches!(
            Request::parse(
                r#"{"op": "inject_fault", "node": "n", "kind": "brownout",
                    "duration_s": 10, "session": 1}"#
            ),
            Err(DalekError::BadRequest(_))
        ));
        // zero-length faults are refused at the wire
        assert!(matches!(
            Request::parse(
                r#"{"op": "inject_fault", "node": "n", "kind": "crash",
                    "duration_s": 0, "session": 1}"#
            ),
            Err(DalekError::BadRequest(_))
        ));
        let r = Response::FaultInjected {
            node: "az4-n4090-0".into(),
            kind: "crash".into(),
        }
        .to_json();
        assert_eq!(r.get("type").unwrap().as_str(), Some("fault_injected"));
        assert_eq!(r.get("kind").unwrap().as_str(), Some("crash"));
    }

    #[test]
    fn query_result_encodes_kind_and_payload() {
        let r = Response::QueryResult {
            expr: "cluster.watts".into(),
            result: crate::query::QueryOutput::Scalar(crate::query::QueryValue::Num(42.5)),
        }
        .to_json();
        assert_eq!(r.get("type").unwrap().as_str(), Some("query_result"));
        assert_eq!(r.get("expr").unwrap().as_str(), Some("cluster.watts"));
        assert_eq!(r.get("kind").unwrap().as_str(), Some("scalar"));
        assert_eq!(r.get("value").unwrap().as_f64(), Some(42.5));
        assert_eq!(r.get("ok").unwrap(), &Json::Bool(true));
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(matches!(
            Request::parse("{}"),
            Err(DalekError::BadRequest(_))
        ));
        assert!(matches!(
            Request::parse(r#"{"op": "warp_drive"}"#),
            Err(DalekError::BadRequest(_))
        ));
        assert!(matches!(
            Request::parse(r#"{"op": "submit_job"}"#),
            Err(DalekError::BadRequest(_))
        ));
        assert!(matches!(
            Request::parse(r#"{"op": "advance", "to_s": -5}"#),
            Err(DalekError::BadRequest(_))
        ));
        // broken JSON surfaces as a wire error
        assert!(matches!(
            Request::parse(r#"{"op": "#),
            Err(DalekError::Wire(_))
        ));
    }

    #[test]
    fn out_of_range_integers_rejected_not_truncated() {
        // 2^32 + 1 must not silently become nodes = 1
        assert!(matches!(
            Request::parse(
                r#"{"op": "submit_job", "partition": "p", "nodes": 4294967297, "duration_s": 1}"#
            ),
            Err(DalekError::BadRequest(_))
        ));
        // GPIO lines are u8: 256 must not wrap to line 0
        assert!(matches!(
            Request::parse(r#"{"op": "set_tag", "node": "n", "line": 256, "high": true}"#),
            Err(DalekError::BadRequest(_))
        ));
        assert!(matches!(
            Request::parse(r#"{"op": "query_samples", "node": "n", "probe": 300, "from_s": 0, "to_s": 1}"#),
            Err(DalekError::BadRequest(_))
        ));
        // u64 fields above 2^53 may already have been rounded by the
        // f64 wire representation — rejected, not silently accepted
        assert!(matches!(
            Request::parse(r#"{"op": "job_info", "job": 9007199254740993}"#),
            Err(DalekError::BadRequest(_))
        ));
    }

    #[test]
    fn payload_jobs_need_no_duration_synthetic_jobs_do() {
        let (_, req) = Request::parse(
            r#"{"op": "submit_job", "session": 1, "partition": "az4-n4090",
                "nodes": 1, "payload": "gemm256", "iters": 100}"#,
        )
        .unwrap();
        let Request::SubmitJob(r) = req else {
            panic!("expected SubmitJob")
        };
        assert_eq!(r.duration, SimTime::ZERO); // sized from the grounding
        assert_eq!(r.payload.as_deref(), Some("gemm256"));
        assert_eq!(r.iters, 100);
        // synthetic jobs must still state a duration
        assert!(matches!(
            Request::parse(r#"{"op": "submit_job", "partition": "p", "nodes": 1}"#),
            Err(DalekError::BadRequest(_))
        ));
    }

    #[test]
    fn power_budget_and_policy_validation() {
        // a non-positive or non-finite budget is rejected
        assert!(matches!(
            Request::parse(r#"{"op": "set_power_budget", "watts": -5}"#),
            Err(DalekError::BadRequest(_))
        ));
        assert!(matches!(
            Request::parse(r#"{"op": "set_power_budget", "watts": 0}"#),
            Err(DalekError::BadRequest(_))
        ));
        // null (like absence) clears the budget
        let (_, req) =
            Request::parse(r#"{"op": "set_power_budget", "watts": null, "session": 1}"#).unwrap();
        assert_eq!(req, Request::SetPowerBudget { watts: None });
        // a mistyped watts must error, not silently clear the budget
        assert!(matches!(
            Request::parse(r#"{"op": "set_power_budget", "watts": "970"}"#),
            Err(DalekError::BadRequest(_))
        ));
        // unknown placement policies are rejected at the wire
        assert!(matches!(
            Request::parse(r#"{"op": "set_policy", "partition": "p", "policy": "lottery"}"#),
            Err(DalekError::BadRequest(_))
        ));
    }

    #[test]
    fn power_report_encodes_optional_budget() {
        let r = Response::PowerReport {
            budget_w: Some(970.0),
            rolling_w: 955.5,
            window_s: 10.0,
            cluster_w: 960.0,
            throttle: 0.31,
            capped_nodes: 16,
            governor_ticks: 120,
            idle_shutdowns: 2,
        }
        .to_json();
        assert_eq!(r.get("budget_w").unwrap().as_f64(), Some(970.0));
        assert_eq!(r.get("capped_nodes").unwrap().as_u64(), Some(16));
        assert_eq!(r.get("type").unwrap().as_str(), Some("power_report"));
        let r = Response::PowerReport {
            budget_w: None,
            rolling_w: 0.0,
            window_s: 10.0,
            cluster_w: 112.0,
            throttle: 1.0,
            capped_nodes: 0,
            governor_ticks: 0,
            idle_shutdowns: 0,
        }
        .to_json();
        assert!(r.get("budget_w").is_none());
    }

    #[test]
    fn responses_encode_with_ok_flag() {
        let ok = Response::Submitted { job: JobId(9) }.to_json();
        assert_eq!(ok.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(ok.get("job").unwrap().as_u64(), Some(9));
        assert_eq!(ok.get("type").unwrap().as_str(), Some("submitted"));
        let err = Response::from_error(&DalekError::AdminOnly).to_json();
        assert_eq!(err.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(
            err.get("error").unwrap().as_str(),
            Some("restricted to administrators")
        );
    }

    #[test]
    fn job_view_encodes_optionals() {
        let v = JobView {
            job: JobId(2),
            user: "alice".into(),
            partition: "az4-n4090".into(),
            state: JobState::Running,
            nodes: 2,
            submitted: SimTime::ZERO,
            started: Some(SimTime::from_secs(90)),
            finished: None,
        };
        let j = Response::Job(v).to_json();
        assert_eq!(j.get("state").unwrap().as_str(), Some("running"));
        assert_eq!(j.get("started_s").unwrap().as_f64(), Some(90.0));
        assert!(j.get("finished_s").is_none());
    }
}
