//! Sessions: log in once, hold a capability-bearing token.
//!
//! The paper's front-ends each re-authenticated on every call (an LDAP
//! lookup plus a MUNGE mint/verify round-trip per RPC). The session
//! layer hoists that to login time: [`SessionManager::login`] resolves
//! the user in the LDAP [`UserDb`], mints a MUNGE credential binding
//! `(uid, login, t)` under the cluster key, verifies it round-trip, and
//! stores it in the session. Every subsequent request presents only the
//! [`SessionId`]; validation re-checks the stored credential's HMAC (so
//! a key rotation invalidates live sessions) and the session's sliding
//! expiry, without touching the directory again.

use std::collections::BTreeMap;

use hmac::{Hmac, Mac as HmacMac};
use sha2::Sha256;

use super::error::DalekError;
use crate::services::auth::{Credential, Munge, UserDb};
use crate::sim::SimTime;

type HmacSha256 = Hmac<Sha256>;

/// An opaque session token handed to the user at login. Tokens are
/// derived from an HMAC under the cluster key (not a counter), so they
/// are unguessable by wire clients — holding one IS the capability.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct SessionId(pub u64);

impl std::fmt::Display for SessionId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "sess-{}", self.0)
    }
}

/// One authenticated session.
#[derive(Clone, Debug)]
pub struct Session {
    pub id: SessionId,
    pub login: String,
    pub uid: u32,
    pub admin: bool,
    pub opened_at: SimTime,
    /// sliding expiry, renewed on every validated request
    pub expires_at: SimTime,
    /// the MUNGE credential minted at login (integrity re-checked on use)
    credential: Credential,
}

/// Issues and validates session tokens against the cluster MUNGE key.
pub struct SessionManager {
    munge: Munge,
    /// key copy for token derivation (tokens must be unguessable)
    key: Vec<u8>,
    /// sliding session lifetime (distinct from the per-credential MUNGE
    /// TTL, which only bounds the login round-trip itself)
    pub ttl: SimTime,
    sessions: BTreeMap<SessionId, Session>,
    counter: u64,
}

impl SessionManager {
    pub fn new(munge_key: &[u8], ttl: SimTime) -> Self {
        Self {
            munge: Munge::new(munge_key),
            key: munge_key.to_vec(),
            ttl,
            sessions: BTreeMap::new(),
            counter: 0,
        }
    }

    /// Tokens are masked to 53 bits so they survive the JSON wire codec
    /// exactly (wire numbers travel as f64, whose exact-integer range is
    /// 2^53); a 53-bit keyed-hash space still makes guessing hopeless.
    const TOKEN_MASK: u64 = (1 << 53) - 1;

    /// Derive an unguessable token: HMAC(key, counter ‖ uid ‖ t). The
    /// counter keeps tokens unique; the HMAC keeps them unpredictable
    /// (and deterministic, preserving replay reproducibility).
    fn mint_token(&mut self, uid: u32, now: SimTime) -> SessionId {
        loop {
            self.counter += 1;
            let mut mac = HmacSha256::new_from_slice(&self.key).expect("any key size");
            mac.update(b"dalek-session-token");
            mac.update(&self.counter.to_le_bytes());
            mac.update(&uid.to_le_bytes());
            mac.update(&now.as_ns().to_le_bytes());
            let bytes = mac.finalize().into_bytes();
            let raw = u64::from_le_bytes(bytes[..8].try_into().expect("32-byte digest"));
            let id = SessionId(raw & Self::TOKEN_MASK);
            if !self.sessions.contains_key(&id) {
                return id;
            }
        }
    }

    /// Authenticate `login` against the directory and open a session.
    pub fn login(&mut self, db: &UserDb, login: &str, now: SimTime) -> Result<Session, DalekError> {
        let user = db.user(login)?;
        let (uid, admin) = (user.uid, user.admin);
        // mint + validate the credential round-trip (what the slurmctld
        // RPC path did per call, §3.4) — proving we hold the key
        let cred = self.munge.encode(uid, login.as_bytes(), now);
        self.munge.decode(&cred, now)?;
        let id = self.mint_token(uid, now);
        let sess = Session {
            id,
            login: login.to_string(),
            uid,
            admin,
            opened_at: now,
            expires_at: now + self.ttl,
            credential: cred,
        };
        self.sessions.insert(id, sess.clone());
        Ok(sess)
    }

    /// Validate a token: known, unexpired, credential HMAC still good
    /// under the current key. Renews the sliding expiry and returns a
    /// snapshot of the session.
    pub fn validate(&mut self, id: SessionId, now: SimTime) -> Result<Session, DalekError> {
        let ttl = self.ttl;
        let sess = self
            .sessions
            .get_mut(&id)
            .ok_or(DalekError::InvalidSession)?;
        if now >= sess.expires_at {
            self.sessions.remove(&id);
            return Err(DalekError::InvalidSession);
        }
        // integrity only: evaluate the HMAC at mint time so the MUNGE
        // per-credential TTL does not cap the session lifetime
        if self
            .munge
            .decode(&sess.credential, sess.credential.minted_at)
            .is_err()
        {
            self.sessions.remove(&id);
            return Err(DalekError::InvalidSession);
        }
        sess.expires_at = sess.expires_at.max(now + ttl);
        Ok(sess.clone())
    }

    /// Close a session; returns whether it existed.
    pub fn logout(&mut self, id: SessionId) -> bool {
        self.sessions.remove(&id).is_some()
    }

    /// Remove every session whose sliding expiry has lapsed by `now`
    /// and return their ids (in token order — deterministic). The
    /// cluster sweeps this on every advance so an expired session's
    /// resources are torn down even if its client never returns;
    /// lazy per-request validation remains the backstop.
    pub fn take_expired(&mut self, now: SimTime) -> Vec<SessionId> {
        let ids: Vec<SessionId> = self
            .sessions
            .iter()
            .filter(|(_, s)| now >= s.expires_at)
            .map(|(id, _)| *id)
            .collect();
        for id in &ids {
            self.sessions.remove(id);
        }
        ids
    }

    pub fn open_count(&self) -> usize {
        self.sessions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn db() -> UserDb {
        let mut db = UserDb::new();
        db.add_user("alice", false).unwrap();
        db.add_user("root", true).unwrap();
        db
    }

    fn mgr() -> SessionManager {
        SessionManager::new(b"dalek-munge-key", SimTime::from_hours(12))
    }

    #[test]
    fn login_issues_distinct_tokens() {
        let (db, mut m) = (db(), mgr());
        let a = m.login(&db, "alice", SimTime::ZERO).unwrap().id;
        let b = m.login(&db, "alice", SimTime::ZERO).unwrap().id;
        assert_ne!(a, b);
        assert_eq!(m.open_count(), 2);
        let s = m.validate(a, SimTime::from_secs(1)).unwrap();
        assert_eq!(s.login, "alice");
        assert!(!s.admin);
        assert!(!m.validate(b, SimTime::from_secs(1)).unwrap().admin);
    }

    #[test]
    fn tokens_are_unguessable_not_sequential() {
        let (db, mut m) = (db(), mgr());
        let a = m.login(&db, "alice", SimTime::ZERO).unwrap().id;
        let b = m.login(&db, "alice", SimTime::ZERO).unwrap().id;
        let c = m.login(&db, "root", SimTime::ZERO).unwrap().id;
        // HMAC-derived: not small counters, not consecutive
        assert_ne!(b.0, a.0 + 1);
        assert_ne!(c.0, b.0 + 1);
        assert!(a.0 > 1000 && b.0 > 1000 && c.0 > 1000);
        // and every token survives the f64 wire representation exactly
        for id in [a, b, c] {
            assert!(id.0 < (1 << 53));
            assert_eq!(id.0 as f64 as u64, id.0);
        }
        // and a fresh manager with a different key mints different tokens
        let mut m2 = SessionManager::new(b"other-key", SimTime::from_hours(12));
        let a2 = m2.login(&db, "alice", SimTime::ZERO).unwrap().id;
        assert_ne!(a2, a);
    }

    #[test]
    fn unknown_user_rejected_at_login() {
        let (db, mut m) = (db(), mgr());
        assert!(matches!(
            m.login(&db, "mallory", SimTime::ZERO),
            Err(DalekError::Auth(_))
        ));
    }

    #[test]
    fn admin_flag_carried() {
        let (db, mut m) = (db(), mgr());
        let r = m.login(&db, "root", SimTime::ZERO).unwrap();
        assert!(r.admin);
        assert!(m.validate(r.id, SimTime::ZERO).unwrap().admin);
    }

    #[test]
    fn bogus_token_rejected() {
        let mut m = mgr();
        assert!(matches!(
            m.validate(SessionId(99), SimTime::ZERO),
            Err(DalekError::InvalidSession)
        ));
    }

    #[test]
    fn session_expires_but_slides_on_use() {
        let (db, mut m) = (db(), mgr());
        let s = m.login(&db, "alice", SimTime::ZERO).unwrap().id;
        // touch at t=11h renews to 23h
        assert!(m.validate(s, SimTime::from_hours(11)).is_ok());
        assert!(m.validate(s, SimTime::from_hours(22)).is_ok());
        // a >ttl gap kills it
        assert!(matches!(
            m.validate(s, SimTime::from_hours(35)),
            Err(DalekError::InvalidSession)
        ));
        assert_eq!(m.open_count(), 0);
    }

    #[test]
    fn session_outlives_munge_credential_ttl() {
        let (db, mut m) = (db(), mgr());
        let s = m.login(&db, "alice", SimTime::ZERO).unwrap().id;
        // MUNGE credential TTL is 5 min; the session must not expire
        // with it — only the session ttl governs
        assert!(m.validate(s, SimTime::from_hours(1)).is_ok());
    }

    #[test]
    fn logout_invalidates() {
        let (db, mut m) = (db(), mgr());
        let s = m.login(&db, "alice", SimTime::ZERO).unwrap().id;
        assert!(m.logout(s));
        assert!(!m.logout(s));
        assert!(matches!(
            m.validate(s, SimTime::ZERO),
            Err(DalekError::InvalidSession)
        ));
    }
}
