//! The unified, session-based cluster API (the paper's user surface,
//! made one protocol).
//!
//! The paper exposes three disjoint user surfaces: the §3.4–3.5 SLURM
//! front-ends (`sbatch`/`srun`/`salloc` with MUNGE credentials), the
//! §4.3 energy-platform API (retrieve samples / tag via GPIO / power
//! control), and the coordinator's reports. This module unifies them
//! behind a single authenticated entry point, the way JetsonLEAP and
//! the D.A.V.I.D.E. cluster put one programmable plane over
//! heterogeneous monitoring and control:
//!
//! * [`session`] — log in once against the LDAP directory, mint/verify
//!   a MUNGE credential, hold a capability-bearing [`SessionId`]
//! * [`protocol`] — the typed [`Request`]/[`Response`] enums and their
//!   JSON wire codec (`util::json`), scriptable via `dalek api`
//! * [`error`] — [`DalekError`], the one error type every subsystem
//!   failure converts into
//! * [`cluster_api`] — [`ClusterApi`], the façade that composes the
//!   scheduler, energy platform, network, services, directory and PJRT
//!   runtime on one `sim::Kernel` ([`ClusterEvent`] is the routing
//!   enum) and routes every request to the (crate-internal)
//!   `SlurmApi`/`EnergyApi` targets
//! * [`events`] — the streaming side: typed [`Event`]s on five
//!   subscription channels (`JobEvents`, `PowerEvents`, `FaultEvents`
//!   — the `dalek::faults` injection/recovery edges — `Telemetry`,
//!   `QueryEvents` — standing DQL queries from [`crate::query`]),
//!   buffered in bounded per-session outboxes with explicit lag
//!   signaling; `run_job`/`alloc_nodes` are nonblocking [`Ticket`]s
//!   with the old blocking semantics rebuilt on top (`wait_job` /
//!   `wait_alloc`)
//! * [`server`] — [`ApiServer`], the deterministic multiplexer: N
//!   concurrent client sessions, round-robin request draining with
//!   per-session rate limits, reproducible bit-for-bit under a seeded
//!   `TraceGen` storm
//!
//! This layer is the seam where a real network transport, request
//! batching and multi-tenant quotas plug in next.

pub mod cluster_api;
pub mod error;
pub mod events;
pub mod protocol;
pub mod server;
pub mod session;

pub use cluster_api::{ClusterApi, ClusterEvent, ClusterReport, FaultEvent, PowerReport};
pub use error::DalekError;
pub use events::{Channel, Event, JobEventKind, PowerEventKind, Ticket};
pub use protocol::{JobRequest, JobView, Request, Response, WIRE_MAJOR};
pub use server::ApiServer;
pub use session::{Session, SessionId, SessionManager};
