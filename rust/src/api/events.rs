//! Typed streaming events: what a subscribed session receives.
//!
//! The §4 energy platform exists to be *watched live*: 1 kSPS probes,
//! governor actuations, job state changes. This module defines the
//! five subscription channels ([`Channel`]) and their event payloads
//! ([`Event`]), plus the bounded per-session [`Outbox`] they buffer in:
//!
//! * `JobEvents` — queued / started / requeued / repriced / finished
//!   (with the measured joules the §6.2 settlement charged), scoped to
//!   the session's own jobs (admins see every job);
//! * `PowerEvents` — governor control ticks, §3.6 cap actuations and
//!   budget violations (admin-only, like the ops that cause them);
//! * `Telemetry` — decimated windows cut from the streaming sampler's
//!   rolling piecewise history at a client-chosen rate. No sample is
//!   materialized: each window is one closed-form integral over the
//!   transition segments, so a 10 Hz subscription costs the same in a
//!   sampled and an unsampled run;
//! * `QueryEvents` — standing DQL queries (`dalek::query`): registered
//!   expressions re-evaluated on a deterministic cadence or on
//!   job/power edges, delivered as deltas (only when the result
//!   changed), owner-scoped like the one-shot `query` op;
//! * `FaultEvents` — `dalek::faults` inject/recover notices (crash,
//!   hang, brownout, throttle, link degradation), admin-only like
//!   `PowerEvents`: the infrastructure view. Non-admin sessions see
//!   the *consequences* on their own jobs as `JobEvents` requeues.
//!
//! Outboxes are bounded; on overflow the oldest events are dropped and
//! the next poll leads with an explicit [`Event::Lagged`] signal, the
//! way `tokio::sync::broadcast` reports lagging receivers — a slow
//! client learns it lost data instead of silently seeing a gap.

use std::collections::VecDeque;

use super::protocol::job_state_str;
use crate::sim::SimTime;
use crate::slurm::{JobId, JobState};
use crate::util::json::Json;

/// Receipt for a nonblocking submission (`run_job` / `alloc_nodes`):
/// the request was accepted and the job queued; progress arrives as
/// `JobEvents`. Blocking semantics are a client-side wait on top
/// (`wait_job` / `wait_alloc`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Ticket(pub u64);

/// The subscription channels.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Channel {
    JobEvents,
    PowerEvents,
    Telemetry,
    QueryEvents,
    FaultEvents,
}

impl Channel {
    pub fn as_str(self) -> &'static str {
        match self {
            Channel::JobEvents => "job_events",
            Channel::PowerEvents => "power_events",
            Channel::Telemetry => "telemetry",
            Channel::QueryEvents => "query_events",
            Channel::FaultEvents => "fault_events",
        }
    }

    pub fn from_wire(s: &str) -> Option<Self> {
        match s {
            "job_events" => Some(Channel::JobEvents),
            "power_events" => Some(Channel::PowerEvents),
            "telemetry" => Some(Channel::Telemetry),
            "query_events" => Some(Channel::QueryEvents),
            "fault_events" => Some(Channel::FaultEvents),
            _ => None,
        }
    }
}

/// One job's lifecycle step, as delivered on the `JobEvents` channel.
#[derive(Clone, Copy, PartialEq, Debug)]
pub enum JobEventKind {
    Queued,
    Started,
    /// the job was evicted by a node fault and put back at the head of
    /// the queue with its work ledger intact (classic jobs) or rolled
    /// back to its last BSP barrier (app jobs)
    Requeued,
    /// a §3.6 knob changed on one of the job's nodes; `rate` is the new
    /// slowest-allocated-node relative execution rate
    Repriced { rate: f64 },
    /// a higher-priority job (or the governor's infeasible-budget path)
    /// claimed this job's nodes: the fair-share grace window is running
    /// and the job will be evicted unless it finishes first
    Preempted,
    /// the job restarted after a preemption eviction, work ledger
    /// intact (classic) or rolled back to its last BSP barrier (app)
    Resumed,
    /// terminal: `joules` is the measured settlement energy the job's
    /// nodes drew while it ran (0 for jobs cancelled before starting)
    Finished { state: JobState, joules: f64 },
}

/// One governor/power-plane step, as delivered on `PowerEvents`.
#[derive(Clone, PartialEq, Debug)]
pub enum PowerEventKind {
    /// one §3.6 control step: measured rolling watts vs the budget
    GovernorTick {
        rolling_w: f64,
        budget_w: f64,
        throttle: f64,
    },
    /// a node's RAPL/dGPU/DVFS knobs were actuated
    CapActuated {
        node: String,
        cpu_cap_w: Option<f64>,
        gpu_cap_w: Option<f64>,
        powersave: bool,
    },
    /// the measured rolling draw exceeded budget × (1 + tolerance)
    BudgetViolation { rolling_w: f64, budget_w: f64 },
}

/// Everything a subscribed session can receive.
#[derive(Clone, PartialEq, Debug)]
pub enum Event {
    Job {
        at: SimTime,
        job: JobId,
        kind: JobEventKind,
    },
    Power {
        at: SimTime,
        kind: PowerEventKind,
    },
    /// one decimated telemetry window: the true piecewise cluster power
    /// integrated over `[from, to)` — no sample materialization
    Telemetry {
        from: SimTime,
        to: SimTime,
        mean_w: f64,
        energy_j: f64,
    },
    /// one standing-query delta on `QueryEvents`: the registered
    /// expression's result changed (`result` is the query's wire
    /// encoding — `{"kind": "scalar" | "vector" | "table", ...}`)
    Query {
        at: SimTime,
        expr: String,
        result: Json,
    },
    /// one fault-plane edge on `FaultEvents`: a `dalek::faults` fault
    /// was injected (`injected`) or recovered (`!injected`) on `node`
    Fault {
        at: SimTime,
        node: String,
        kind: crate::faults::FaultKind,
        injected: bool,
    },
    /// the outbox overflowed (or telemetry windows aged past the
    /// rolling-history horizon): `missed` events/windows were dropped
    Lagged { missed: u64 },
}

impl Event {
    /// Encode for the wire (`poll_events` replies, the `dalek api`
    /// batch transcript). Events are server → client only; there is no
    /// decoder.
    pub fn to_json(&self) -> Json {
        match self {
            Event::Job { at, job, kind } => {
                let mut fields: Vec<(&str, Json)> = vec![
                    ("event", Json::from("job")),
                    ("at_s", Json::from(at.as_secs_f64())),
                    ("job", Json::from(job.0)),
                ];
                match kind {
                    JobEventKind::Queued => fields.push(("kind", Json::from("queued"))),
                    JobEventKind::Started => fields.push(("kind", Json::from("started"))),
                    JobEventKind::Requeued => fields.push(("kind", Json::from("requeued"))),
                    JobEventKind::Preempted => fields.push(("kind", Json::from("preempted"))),
                    JobEventKind::Resumed => fields.push(("kind", Json::from("resumed"))),
                    JobEventKind::Repriced { rate } => {
                        fields.push(("kind", Json::from("repriced")));
                        fields.push(("rate", Json::from(*rate)));
                    }
                    JobEventKind::Finished { state, joules } => {
                        fields.push(("kind", Json::from("finished")));
                        fields.push(("state", Json::from(job_state_str(*state))));
                        fields.push(("joules", Json::from(*joules)));
                    }
                }
                Json::object(fields)
            }
            Event::Power { at, kind } => {
                let mut fields: Vec<(&str, Json)> = vec![
                    ("event", Json::from("power")),
                    ("at_s", Json::from(at.as_secs_f64())),
                ];
                match kind {
                    PowerEventKind::GovernorTick {
                        rolling_w,
                        budget_w,
                        throttle,
                    } => {
                        fields.push(("kind", Json::from("governor_tick")));
                        fields.push(("rolling_w", Json::from(*rolling_w)));
                        fields.push(("budget_w", Json::from(*budget_w)));
                        fields.push(("throttle", Json::from(*throttle)));
                    }
                    PowerEventKind::CapActuated {
                        node,
                        cpu_cap_w,
                        gpu_cap_w,
                        powersave,
                    } => {
                        fields.push(("kind", Json::from("cap_actuated")));
                        fields.push(("node", Json::from(node.as_str())));
                        if let Some(c) = cpu_cap_w {
                            fields.push(("cpu_cap_w", Json::from(*c)));
                        }
                        if let Some(g) = gpu_cap_w {
                            fields.push(("gpu_cap_w", Json::from(*g)));
                        }
                        fields.push(("powersave", Json::from(*powersave)));
                    }
                    PowerEventKind::BudgetViolation {
                        rolling_w,
                        budget_w,
                    } => {
                        fields.push(("kind", Json::from("budget_violation")));
                        fields.push(("rolling_w", Json::from(*rolling_w)));
                        fields.push(("budget_w", Json::from(*budget_w)));
                    }
                }
                Json::object(fields)
            }
            Event::Telemetry {
                from,
                to,
                mean_w,
                energy_j,
            } => Json::object([
                ("event", Json::from("telemetry")),
                ("from_s", Json::from(from.as_secs_f64())),
                ("to_s", Json::from(to.as_secs_f64())),
                ("mean_w", Json::from(*mean_w)),
                ("energy_j", Json::from(*energy_j)),
            ]),
            Event::Query { at, expr, result } => Json::object([
                ("event", Json::from("query")),
                ("at_s", Json::from(at.as_secs_f64())),
                ("expr", Json::from(expr.as_str())),
                ("result", result.clone()),
            ]),
            Event::Fault {
                at,
                node,
                kind,
                injected,
            } => {
                let mut fields: Vec<(&str, Json)> = vec![
                    ("event", Json::from("fault")),
                    ("at_s", Json::from(at.as_secs_f64())),
                    ("node", Json::from(node.as_str())),
                    ("kind", Json::from(kind.label())),
                    ("injected", Json::from(*injected)),
                ];
                match kind {
                    crate::faults::FaultKind::Brownout { floor_w } => {
                        fields.push(("floor_w", Json::from(*floor_w)))
                    }
                    crate::faults::FaultKind::Throttle { factor } => {
                        fields.push(("factor", Json::from(*factor)))
                    }
                    crate::faults::FaultKind::LinkDegrade { fraction } => {
                        fields.push(("fraction", Json::from(*fraction)))
                    }
                    crate::faults::FaultKind::Crash | crate::faults::FaultKind::Hang => {}
                }
                Json::object(fields)
            }
            Event::Lagged { missed } => Json::object([
                ("event", Json::from("lagged")),
                ("missed", Json::from(*missed)),
            ]),
        }
    }
}

/// A bounded per-session event buffer. Overflow drops the oldest
/// events and records the count; the next drain leads with one
/// [`Event::Lagged`] carrying it.
#[derive(Debug)]
pub(crate) struct Outbox {
    buf: VecDeque<Event>,
    cap: usize,
    missed: u64,
}

impl Outbox {
    pub(crate) fn new(cap: usize) -> Self {
        Self {
            buf: VecDeque::new(),
            cap: cap.max(1),
            missed: 0,
        }
    }

    pub(crate) fn push(&mut self, ev: Event) {
        if self.buf.len() >= self.cap {
            self.buf.pop_front();
            self.missed += 1;
        }
        self.buf.push_back(ev);
    }

    /// Record `n` missed items directly, without touching the buffer
    /// (telemetry windows that aged past the rolling horizon and were
    /// never materialized).
    pub(crate) fn lag(&mut self, n: u64) {
        self.missed += n;
    }

    /// Retarget the capacity; if the buffer already exceeds it, the
    /// overflow is dropped (oldest first) and counted as missed.
    pub(crate) fn set_cap(&mut self, cap: usize) {
        self.cap = cap.max(1);
        while self.buf.len() > self.cap {
            self.buf.pop_front();
            self.missed += 1;
        }
    }

    /// Take up to `max` events; a pending lag signal comes first and
    /// counts toward `max`.
    pub(crate) fn drain(&mut self, max: usize) -> Vec<Event> {
        let mut out = Vec::new();
        if max == 0 {
            return out;
        }
        if self.missed > 0 {
            out.push(Event::Lagged {
                missed: self.missed,
            });
            self.missed = 0;
        }
        while out.len() < max {
            match self.buf.pop_front() {
                Some(e) => out.push(e),
                None => break,
            }
        }
        out
    }

    pub(crate) fn len(&self) -> usize {
        self.buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_names_round_trip() {
        for c in [
            Channel::JobEvents,
            Channel::PowerEvents,
            Channel::Telemetry,
            Channel::QueryEvents,
            Channel::FaultEvents,
        ] {
            assert_eq!(Channel::from_wire(c.as_str()), Some(c));
        }
        assert_eq!(Channel::from_wire("exterminate"), None);
    }

    #[test]
    fn outbox_bounds_and_signals_lag() {
        let mut o = Outbox::new(3);
        for i in 0..5u64 {
            o.push(Event::Lagged { missed: 100 + i }); // payload irrelevant
        }
        assert_eq!(o.len(), 3);
        let drained = o.drain(10);
        // 2 dropped -> leading Lagged{2}, then the surviving 3
        assert_eq!(drained.len(), 4);
        assert_eq!(drained[0], Event::Lagged { missed: 2 });
        // lag cleared after reporting
        assert!(o.drain(10).is_empty());
    }

    #[test]
    fn outbox_drain_respects_max() {
        let mut o = Outbox::new(10);
        for _ in 0..5 {
            o.push(Event::Lagged { missed: 9 });
        }
        assert_eq!(o.drain(2).len(), 2);
        assert_eq!(o.drain(100).len(), 3);
    }

    #[test]
    fn shrinking_cap_drops_oldest_and_counts() {
        let mut o = Outbox::new(8);
        for i in 0..6u64 {
            o.push(Event::Lagged { missed: i });
        }
        o.set_cap(2);
        assert_eq!(o.len(), 2);
        let d = o.drain(10);
        assert_eq!(d[0], Event::Lagged { missed: 4 });
        assert_eq!(d.len(), 3);
    }

    #[test]
    fn event_json_shapes() {
        let e = Event::Job {
            at: SimTime::from_secs(70),
            job: JobId(3),
            kind: JobEventKind::Finished {
                state: JobState::Completed,
                joules: 123.5,
            },
        }
        .to_json();
        assert_eq!(e.get("event").unwrap().as_str(), Some("job"));
        assert_eq!(e.get("kind").unwrap().as_str(), Some("finished"));
        assert_eq!(e.get("state").unwrap().as_str(), Some("completed"));
        assert_eq!(e.get("joules").unwrap().as_f64(), Some(123.5));
        let t = Event::Telemetry {
            from: SimTime::ZERO,
            to: SimTime::from_ms(100),
            mean_w: 42.0,
            energy_j: 4.2,
        }
        .to_json();
        assert_eq!(t.get("event").unwrap().as_str(), Some("telemetry"));
        assert_eq!(t.get("mean_w").unwrap().as_f64(), Some(42.0));
        let l = Event::Lagged { missed: 7 }.to_json();
        assert_eq!(l.get("missed").unwrap().as_u64(), Some(7));
        let f = Event::Fault {
            at: SimTime::from_secs(5),
            node: "az5-a890m-0".into(),
            kind: crate::faults::FaultKind::Brownout { floor_w: 180.0 },
            injected: true,
        }
        .to_json();
        assert_eq!(f.get("event").unwrap().as_str(), Some("fault"));
        assert_eq!(f.get("kind").unwrap().as_str(), Some("brownout"));
        assert_eq!(f.get("floor_w").unwrap().as_f64(), Some(180.0));
        assert_eq!(f.get("injected").unwrap().as_bool(), Some(true));
        let r = Event::Fault {
            at: SimTime::from_secs(6),
            node: "az5-a890m-0".into(),
            kind: crate::faults::FaultKind::Crash,
            injected: false,
        }
        .to_json();
        assert_eq!(r.get("kind").unwrap().as_str(), Some("crash"));
        assert_eq!(r.get("injected").unwrap().as_bool(), Some(false));
        assert!(r.get("floor_w").is_none());
    }
}
