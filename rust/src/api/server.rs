//! `ApiServer` — the deterministic multi-client multiplexer.
//!
//! One server owns the composed [`ClusterApi`] and N concurrent client
//! sessions, each with a FIFO request queue. Draining is round-robin —
//! one request per client per round, so no client can starve another —
//! under a per-client *rate limit*: at most `ops_per_drain` requests
//! per [`ApiServer::drain`] call (admins override it per user with the
//! `set_rate_limit` op; excess requests stay queued, they are never
//! dropped). Capability scoping is the session layer's: an admin op
//! enqueued by a non-admin comes back as the same error it would over
//! the wire.
//!
//! Everything is deterministic by construction: clients drain in
//! connect order, queues are FIFO, the cluster below is seeded, and no
//! wall clock or OS entropy is consulted — so a seeded
//! [`TraceGen::client_storm`](crate::coordinator::trace::TraceGen::client_storm)
//! replayed through [`ApiServer::run_storm`] produces bit-identical
//! transcripts (responses *and* polled events) across runs. That
//! reproducibility is pinned by `tests/streaming_api.rs` and is the
//! contract every later scale-out layer (sharding, remote transports)
//! must preserve.

use std::collections::{BTreeSet, VecDeque};

use super::cluster_api::ClusterApi;
use super::error::DalekError;
use super::events::Event;
use super::protocol::{Request, Response};
use super::session::SessionId;
use crate::coordinator::trace::StormEvent;
use crate::sim::SimTime;

/// Default per-drain request budget of a client (overridable per user
/// through the admin `set_rate_limit` op).
pub const DEFAULT_OPS_PER_DRAIN: u32 = 8;

/// One connected client: a session plus its FIFO queue and transcript.
pub struct Client {
    pub user: String,
    pub sid: SessionId,
    queue: VecDeque<Request>,
    /// every response this client received, as wire JSON lines — the
    /// bit-identity surface of the determinism tests
    pub transcript: Vec<String>,
    /// max requests served per `drain` call (rate limit)
    pub ops_per_drain: u32,
    /// total requests served
    pub served: u64,
}

/// The deterministic multiplexer over one [`ClusterApi`].
pub struct ApiServer {
    pub cluster: ClusterApi,
    clients: Vec<Client>,
    /// sparse ready-set: exactly the client indices whose queue is
    /// nonempty, in ascending (= connect) order. Serving a request can
    /// never enqueue one, so a drain only ever shrinks this set — the
    /// snapshot taken at drain start covers every client the drain can
    /// legally touch, and iterating it in order reproduces the dense
    /// full-scan round-robin with the empty-queue no-ops elided.
    ready: BTreeSet<usize>,
    /// maintained mirror of the summed queue lengths
    queued: usize,
}

impl ApiServer {
    pub fn new(cluster: ClusterApi) -> Self {
        Self {
            cluster,
            clients: Vec::new(),
            ready: BTreeSet::new(),
            queued: 0,
        }
    }

    /// Open a session for `user` (provisioning the account if needed)
    /// and register the client; returns its index. Client order is
    /// fairness order.
    pub fn connect(&mut self, user: &str) -> Result<usize, DalekError> {
        if user != "root" {
            self.cluster.add_user(user);
        }
        let sid = self.cluster.login(user)?;
        self.clients.push(Client {
            user: user.to_string(),
            sid,
            queue: VecDeque::new(),
            transcript: Vec::new(),
            ops_per_drain: DEFAULT_OPS_PER_DRAIN,
            served: 0,
        });
        Ok(self.clients.len() - 1)
    }

    pub fn client_count(&self) -> usize {
        self.clients.len()
    }

    pub fn client(&self, idx: usize) -> &Client {
        &self.clients[idx]
    }

    /// Queue one request on a client (FIFO; served at the next drain).
    pub fn enqueue(&mut self, client: usize, req: Request) {
        self.clients[client].queue.push_back(req);
        self.ready.insert(client);
        self.queued += 1;
    }

    /// Queued-but-unserved request count across all clients.
    pub fn backlog(&self) -> usize {
        debug_assert_eq!(
            self.queued,
            self.clients.iter().map(|c| c.queue.len()).sum::<usize>(),
            "maintained backlog counter diverged from the queue scan"
        );
        self.queued
    }

    /// One drain: round-robin over the clients in connect order, one
    /// request per client per round, until every queue is empty or
    /// every client exhausted its per-drain budget. Requests past the
    /// budget stay queued for the next drain — rate limiting delays,
    /// it never drops.
    ///
    /// Only the sparse ready-set is walked: per round the serve order
    /// is the ascending-index subsequence of clients holding requests,
    /// which is exactly the dense 0..n scan minus its no-op visits —
    /// same serves, same order, same transcripts.
    pub fn drain(&mut self) {
        debug_assert!(self
            .ready
            .iter()
            .all(|&ci| !self.clients[ci].queue.is_empty()));
        debug_assert!((0..self.clients.len())
            .all(|ci| self.clients[ci].queue.is_empty() || self.ready.contains(&ci)));
        // budget snapshot at drain start, as in the dense scan: a
        // mid-drain SetRateLimit changes `ops_per_drain` for *future*
        // drains only
        let mut active: Vec<usize> = self.ready.iter().copied().collect();
        let mut budget: Vec<u32> = active
            .iter()
            .map(|&ci| self.clients[ci].ops_per_drain)
            .collect();
        while !active.is_empty() {
            let mut next_active = Vec::with_capacity(active.len());
            let mut next_budget = Vec::with_capacity(active.len());
            for (k, &ci) in active.iter().enumerate() {
                let req = self.clients[ci]
                    .queue
                    .pop_front()
                    .expect("ready clients hold at least one request");
                self.queued -= 1;
                let resp = self.execute(ci, &req);
                let line = resp.to_json().to_string();
                let c = &mut self.clients[ci];
                c.transcript.push(line);
                c.served += 1;
                let left = budget[k] - 1;
                if self.clients[ci].queue.is_empty() {
                    self.ready.remove(&ci);
                } else if left > 0 {
                    next_active.push(ci);
                    next_budget.push(left);
                }
            }
            active = next_active;
            budget = next_budget;
        }
    }

    /// Drain until every queue is empty, however many rate-limit
    /// rounds that takes.
    pub fn drain_all(&mut self) {
        while self.backlog() > 0 {
            self.drain();
        }
    }

    fn execute(&mut self, ci: usize, req: &Request) -> Response {
        let sid = self.clients[ci].sid;
        match self.cluster.handle(Some(sid), req) {
            Ok(resp) => {
                // the rate-limit override is server-scoped: the session
                // layer validated the capability and the user, the
                // budget itself lives here
                if let (Request::SetRateLimit { user, ops }, Response::RateLimitSet { .. }) =
                    (req, &resp)
                {
                    for c in &mut self.clients {
                        if &c.user == user {
                            c.ops_per_drain = (*ops).max(1);
                        }
                    }
                }
                resp
            }
            Err(e) => Response::from_error(&e),
        }
    }

    /// Advance the cluster below (events, governor, app engine) to `t`
    /// without sampling.
    pub fn run_until(&mut self, t: SimTime) {
        self.cluster.run_until(t, false);
    }

    /// Replay a seeded multi-client storm: arrivals are processed in
    /// time order — the cluster is driven to each arrival batch's
    /// timestamp, the batch is enqueued, and the queues drained
    /// round-robin. Deterministic end to end.
    pub fn run_storm(&mut self, storm: &[StormEvent]) {
        let mut i = 0;
        while i < storm.len() {
            let at = storm[i].at;
            self.run_until(at);
            while i < storm.len() && storm[i].at == at {
                self.enqueue(storm[i].client, storm[i].request.clone());
                i += 1;
            }
            self.drain();
        }
    }

    /// Quiesce after a storm: drive to `until`, serve any rate-limited
    /// backlog, then have every client poll its remaining events so
    /// they land in the transcript.
    pub fn settle(&mut self, until: SimTime) {
        self.run_until(until);
        self.drain_all();
        for ci in 0..self.clients.len() {
            self.enqueue(ci, Request::PollEvents { max: u32::MAX });
        }
        self.drain_all();
    }

    /// Drain a client's buffered events directly (tests, dashboards).
    pub fn take_events(&mut self, client: usize) -> Vec<Event> {
        let sid = self.clients[client].sid;
        self.cluster.take_events(sid, usize::MAX)
    }

    /// The full per-client transcripts joined into one comparable
    /// digest (client index prefixes keep interleavings apart).
    pub fn transcript_digest(&self) -> String {
        let mut out = String::new();
        for (ci, c) in self.clients.iter().enumerate() {
            for line in &c.transcript {
                out.push_str(&format!("{ci} {line}\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::protocol::JobRequest;
    use crate::config::ClusterConfig;
    use crate::slurm::JobState;

    fn server() -> ApiServer {
        ApiServer::new(ClusterApi::new(ClusterConfig::dalek_default(), None).unwrap())
    }

    fn submit(partition: &str, secs: u64) -> Request {
        Request::SubmitJob(JobRequest {
            partition: partition.into(),
            nodes: 1,
            duration: SimTime::from_secs(secs),
            time_limit: None,
            payload: None,
            iters: 1,
            user: None,
            app: None,
        })
    }

    #[test]
    fn round_robin_interleaves_clients_fairly() {
        let mut s = server();
        let a = s.connect("alice").unwrap();
        let b = s.connect("bob").unwrap();
        // alice floods; bob sends one — bob is served in round one
        for _ in 0..6 {
            s.enqueue(a, Request::ClusterReport);
        }
        s.enqueue(b, Request::ClusterReport);
        s.drain();
        assert_eq!(s.client(a).served, 6);
        assert_eq!(s.client(b).served, 1);
        assert_eq!(s.backlog(), 0);
    }

    #[test]
    fn rate_limit_defers_but_never_drops() {
        let mut s = server();
        let root = s.connect("root").unwrap();
        let a = s.connect("alice").unwrap();
        s.enqueue(
            root,
            Request::SetRateLimit {
                user: "alice".into(),
                ops: 2,
            },
        );
        s.drain();
        for _ in 0..5 {
            s.enqueue(a, Request::ClusterReport);
        }
        s.drain();
        assert_eq!(s.client(a).served, 2);
        assert_eq!(s.backlog(), 3);
        s.drain();
        assert_eq!(s.client(a).served, 4);
        s.drain_all();
        assert_eq!(s.client(a).served, 5);
        assert_eq!(s.backlog(), 0);
        // every response was recorded
        assert_eq!(s.client(a).transcript.len(), 5);
    }

    #[test]
    fn non_admin_rate_limit_override_is_refused() {
        let mut s = server();
        let a = s.connect("alice").unwrap();
        let before = s.client(a).ops_per_drain;
        s.enqueue(
            a,
            Request::SetRateLimit {
                user: "alice".into(),
                ops: 1_000,
            },
        );
        s.drain();
        assert_eq!(s.client(a).ops_per_drain, before, "no self-service limits");
        assert!(s.client(a).transcript[0].contains("restricted to administrators"));
    }

    #[test]
    fn sparse_ready_set_serves_only_loaded_clients() {
        let mut s = server();
        let ids: Vec<usize> = (0..8).map(|i| s.connect(&format!("u{i}")).unwrap()).collect();
        // scattered load: most clients stay idle and are never visited
        s.enqueue(ids[6], Request::ClusterReport);
        s.enqueue(ids[1], Request::ClusterReport);
        s.enqueue(ids[1], Request::ClusterReport);
        s.enqueue(ids[3], Request::ClusterReport);
        assert_eq!(s.backlog(), 4);
        s.drain();
        assert_eq!(s.backlog(), 0);
        for (i, &c) in ids.iter().enumerate() {
            let want = match i {
                1 => 2,
                3 | 6 => 1,
                _ => 0,
            };
            assert_eq!(s.client(c).served, want, "client {i}");
        }
        // a later enqueue re-readies the client
        s.enqueue(ids[3], Request::ClusterReport);
        s.drain();
        assert_eq!(s.client(ids[3]).served, 2);
        assert_eq!(s.backlog(), 0);
    }

    #[test]
    fn storm_of_tickets_completes_jobs() {
        let mut s = server();
        let a = s.connect("alice").unwrap();
        s.enqueue(a, submit("az5-a890m", 60));
        s.enqueue(
            a,
            Request::Subscribe {
                channel: crate::api::Channel::JobEvents,
                rate_hz: None,
                expr: None,
            },
        );
        s.drain();
        s.run_until(SimTime::from_mins(10));
        let events = s.take_events(a);
        assert!(!events.is_empty());
        let done = s
            .cluster
            .slurm()
            .jobs()
            .filter(|j| j.state == JobState::Completed)
            .count();
        assert_eq!(done, 1);
    }
}
