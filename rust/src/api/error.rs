//! The unified error type of the `dalek::api` protocol layer.
//!
//! Every subsystem error converts into [`DalekError`], so a protocol
//! handler (and the wire codec) deal with exactly one failure surface.
//! Crate-internal routing-target errors (`slurm::api::ApiError`,
//! `energy::api::ApiError`) are flattened rather than wrapped, keeping
//! the public interface free of `pub(crate)` types.

use crate::energy::board::BoardError;
use crate::services::auth::AuthError;
use crate::slurm::scheduler::SlurmError;
use crate::slurm::JobId;
use crate::util::json::JsonError;

/// Everything that can go wrong behind the [`super::ClusterApi`].
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum DalekError {
    #[error(transparent)]
    Auth(#[from] AuthError),
    #[error("invalid or expired session")]
    InvalidSession,
    #[error("restricted to administrators")]
    AdminOnly,
    #[error(transparent)]
    Slurm(#[from] SlurmError),
    #[error(transparent)]
    Board(#[from] BoardError),
    #[error("no energy board for node `{0}`")]
    NoBoard(String),
    #[error("unknown job {0}")]
    UnknownJob(JobId),
    #[error("job did not reach a terminal state")]
    Incomplete,
    #[error("deadline reached before {0} finished; pending work was cancelled")]
    Deadline(JobId),
    #[error("malformed request: {0}")]
    BadRequest(String),
    #[error("invalid query: {0}")]
    InvalidQuery(String),
    #[error(transparent)]
    Wire(#[from] JsonError),
    #[error("no PJRT runtime loaded (run `make artifacts`)")]
    NoRuntime,
    #[error("runtime error: {0}")]
    Runtime(String),
}

impl From<crate::slurm::api::ApiError> for DalekError {
    fn from(e: crate::slurm::api::ApiError) -> Self {
        use crate::slurm::api::ApiError as E;
        match e {
            E::Auth(a) => DalekError::Auth(a),
            E::Slurm(s) => DalekError::Slurm(s),
        }
    }
}

impl From<crate::energy::api::ApiError> for DalekError {
    fn from(e: crate::energy::api::ApiError) -> Self {
        use crate::energy::api::ApiError as E;
        match e {
            E::Board(b) => DalekError::Board(b),
            E::NoBoard(n) => DalekError::NoBoard(n),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subsystem_errors_flatten() {
        let e: DalekError = AuthError::UnknownUser("mallory".into()).into();
        assert!(matches!(e, DalekError::Auth(_)));
        let e: DalekError = SlurmError::UnknownPartition("nope".into()).into();
        assert!(matches!(e, DalekError::Slurm(_)));
        let e: DalekError =
            crate::slurm::api::ApiError::Slurm(SlurmError::NotPending(JobId(3))).into();
        assert!(matches!(e, DalekError::Slurm(SlurmError::NotPending(_))));
        let e: DalekError = crate::energy::api::ApiError::NoBoard("n0".into()).into();
        assert_eq!(e, DalekError::NoBoard("n0".into()));
    }

    #[test]
    fn messages_are_user_facing() {
        assert_eq!(
            DalekError::AdminOnly.to_string(),
            "restricted to administrators"
        );
        assert!(DalekError::BadRequest("missing `op`".into())
            .to_string()
            .contains("missing `op`"));
    }
}
