//! Bench: the DQL evaluator over a 10k-node virtual tree.
//!
//! The evaluator's contract is lazy projection: resolution walks only
//! the paths an expression names, so a wildcard over 10k nodes touches
//! 10k leaves exactly once, a predicate filter reads one attribute per
//! candidate, and windowed aggregation asks the tree for one
//! closed-form number per matched path — never a sample. This bench
//! times the four expression shapes the API serves hottest (wildcard
//! fan-out, predicate count, filtered windowed mean, full-tree max)
//! against a synthetic [`MemTree`] cluster 625× the paper's testbed.

use dalek::bench::perf::synthetic_tree;
use dalek::query::{self, Expr};
use dalek::util::benchkit;

const NODES: usize = 10_000;

fn main() {
    println!("=== DQL evaluator — {NODES}-node virtual tree ===\n");
    let tree = synthetic_tree(NODES);

    let cases = [
        ("wildcard vector", "nodes.*.power.watts"),
        ("predicate count", "count(nodes[capped=true])"),
        ("filtered windowed mean", "mean(nodes[partition=\"p7\"].power.watts, window=60s)"),
        ("full-tree aggregate", "sum(nodes.*.power.watts)"),
    ];

    // correctness anchor before timing: the shapes evaluate
    for (_, src) in &cases {
        let e = Expr::parse(src).expect("static expression");
        query::eval(&tree, &e).expect("evaluates");
    }

    for (label, src) in &cases {
        let e = Expr::parse(src).expect("static expression");
        let r = benchkit::bench(&format!("query_eval/{label}"), 2, 20, || {
            std::hint::black_box(query::eval(&tree, &e).expect("evaluates"));
        });
        let wall_s = r.summary.p50 / 1e9;
        println!("{}\n  nodes visited/s: {:.1} M\n", r.report(), NODES as f64 / wall_s / 1e6);
    }
}
