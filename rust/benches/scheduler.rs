//! Bench: the SLURM controller hot paths — submission + scheduling
//! throughput, the suspend/resume machinery, and the event queue.
//! Perf target (DESIGN.md §6): simulate a 24 h cluster day ≪ real time.

use dalek::config::ClusterConfig;
use dalek::power::Activity;
use dalek::sim::{EventQueue, SimTime};
use dalek::slurm::{JobSpec, SlurmSim};
use dalek::util::benchkit;

fn day_of_jobs(n: u64) -> Vec<(SimTime, JobSpec)> {
    (0..n)
        .map(|i| {
            let part = ["az4-n4090", "az4-a7900", "iml-ia770", "az5-a890m"][(i % 4) as usize];
            let spec = JobSpec {
                user: format!("u{}", i % 5),
                partition: part.into(),
                nodes: 1 + (i % 4) as u32,
                duration: SimTime::from_secs(60 + (i % 7) * 45),
                time_limit: SimTime::from_mins(30),
                payload: None,
                activity: Activity::cpu_only(0.9),
                app: None,
            };
            (SimTime::from_secs(i * 97), spec)
        })
        .collect()
}

fn main() {
    println!("=== scheduler / event-queue hot paths ===\n");

    let jobs = day_of_jobs(800); // ~21 h of arrivals at ~97 s spacing
    let r = benchkit::bench("slurm/day(800 jobs, 16 nodes, suspend ON)", 1, 10, || {
        let mut s = SlurmSim::from_config(&ClusterConfig::dalek_default());
        for (at, spec) in &jobs {
            s.submit_at(spec.clone(), *at).expect("valid");
        }
        s.run_to_idle();
        assert_eq!(s.stats.completed, 800);
        std::hint::black_box(s.total_energy_j());
    });
    println!(
        "simulated-day speedup vs wall clock: {:.0}x   jobs/s: {:.0}\n",
        24.0 * 3600.0 / (r.summary.p50 / 1e9),
        benchkit::per_sec(&r, 800.0)
    );

    let r = benchkit::bench("eventqueue/schedule+pop 100k", 2, 20, || {
        let mut q: EventQueue<u64> = EventQueue::new();
        for i in 0..100_000u64 {
            q.schedule_at(SimTime::from_ns(i * 13 % 1_000_000), i);
        }
        let mut acc = 0u64;
        while let Some((_, e)) = q.pop() {
            acc ^= e;
        }
        std::hint::black_box(acc);
    });
    println!(
        "events/s: {:.1} M\n",
        benchkit::per_sec(&r, 200_000.0) / 1e6
    );

    benchkit::bench("eventqueue/cancel-heavy (50k timers, all cancelled)", 2, 20, || {
        let mut q: EventQueue<u32> = EventQueue::new();
        let ids: Vec<_> = (0..50_000u32)
            .map(|i| q.schedule_at(SimTime::from_secs(600 + i as u64), i))
            .collect();
        for id in ids {
            q.cancel(id);
        }
        assert!(q.pop().is_none());
    });
}
