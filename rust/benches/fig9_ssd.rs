//! Bench: regenerate paper Fig. 9 (SSD throughput, dd/iozone).

use dalek::bench::ssd;
use dalek::util::benchkit;

fn main() {
    println!("=== Fig. 9 — SSD throughput ===\n");
    ssd::render(&ssd::run_all(0xDA1EC, true)).print();
    println!("\n--- executor timing ---");
    benchkit::bench("fig9/run_all(3 SSDs x 4 patterns)", 3, 100, || {
        let p = ssd::run_all(1, true);
        std::hint::black_box(p.len());
    });
}
