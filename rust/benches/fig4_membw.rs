//! Bench: regenerate paper Fig. 4 (CPU memory bandwidth) and time the
//! executor (the fig4 sweep is also a hot path of `dalek bench all`).

use dalek::bench::membw;
use dalek::hw::CacheLevel;
use dalek::util::benchkit;

fn main() {
    println!("=== Fig. 4 — CPU memory throughput (bandwidth benchmark) ===\n");
    let points = membw::run_all(0xDA1EC, true);
    for lvl in [CacheLevel::L1, CacheLevel::L2, CacheLevel::L3, CacheLevel::Ram] {
        membw::render(&points, lvl).print();
        println!();
    }
    println!("--- executor timing ---");
    let r = benchkit::bench("fig4/run_all(4 CPUs, 6 kernels, 19 sizes)", 3, 30, || {
        let p = membw::run_all(1, true);
        std::hint::black_box(p.len());
    });
    println!(
        "points/s: {:.0}\n",
        benchkit::per_sec(&r, points.len() as f64)
    );
}
