//! Bench: regenerate paper Fig. 6 (GPU global-memory bandwidth, clpeak).

use dalek::bench::clpeak;
use dalek::util::benchkit;

fn main() {
    println!("=== Fig. 6 — GPU global memory throughput (clpeak copy) ===\n");
    clpeak::render_gmem(&clpeak::run_all_gmem(0xDA1EC, true)).print();
    println!("\n--- executor timing ---");
    benchkit::bench("fig6/run_all(7 GPUs x 5 pack widths)", 3, 100, || {
        let p = clpeak::run_all_gmem(1, true);
        std::hint::black_box(p.len());
    });
}
