//! Bench: the `dalek::app` phase/collective model under fabric load.
//!
//! Sweeps rank count x fabric load for an allreduce-loop app on the
//! iml-ia770 partition (5 GbE NICs). Fabric load is background bulk
//! traffic from the frontend into the app's own nodes — the NFS/PXE
//! kind of pressure §6.2 warns about — so the collective phases share
//! downlinks with it and the BSP barrier stretches. Prints makespan,
//! the app job's settled energy and the fabric bytes its collectives
//! moved; also times the replay (phase events must not blow up the
//! simulation wall time).

use dalek::api::ClusterApi;
use dalek::app::AppSpec;
use dalek::config::ClusterConfig;
use dalek::sim::SimTime;
use dalek::slurm::{JobSpec, JobState};
use dalek::util::{benchkit, Table};

const PARTITION: &str = "iml-ia770";
/// per-iteration compute per rank, seconds
const WORK_S: f64 = 20.0;
/// gradient buffer each iteration allreduces
const GRAD_BYTES: u64 = 400_000_000; // 400 MB -> ~1 s/ring hop at 5 GbE
const ITERS: u32 = 6;
/// one background transfer's size (big enough to outlast the app)
const BG_BYTES: u64 = 200_000_000_000;

struct Outcome {
    makespan_s: f64,
    job_energy_j: f64,
    collective_bytes: f64,
    wall_s: f64,
}

fn run(ranks: u32, bg_flows: u32) -> Outcome {
    let mut c = ClusterApi::new(ClusterConfig::dalek_default(), None).expect("cluster");
    // background fabric load: frontend -> the partition's nodes
    for i in 0..bg_flows {
        let dst = format!("{PARTITION}-{}", i % 4);
        c.start_transfer("front", &dst, BG_BYTES).expect("hosts");
    }
    let app = AppSpec::allreduce_loop("cnn-train", WORK_S, GRAD_BYTES, ITERS);
    let t0 = std::time::Instant::now();
    let id = c
        .submit(JobSpec::app("root", PARTITION, app, ranks), SimTime::ZERO)
        .expect("valid app");
    // drive until the app (not the background bulk) is done
    let mut horizon = SimTime::from_mins(10);
    while !c.slurm().job(id).expect("submitted").is_terminal() {
        c.run_until(horizon, false);
        horizon += SimTime::from_mins(10);
        assert!(horizon < SimTime::from_hours(12), "app failed to drain");
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let job = c.slurm().job(id).expect("submitted");
    assert_eq!(job.state, JobState::Completed, "app must complete");
    Outcome {
        makespan_s: job.finished.expect("terminal").as_secs_f64(),
        job_energy_j: job.energy_j,
        collective_bytes: c.apps().stats.collective_bytes,
        wall_s,
    }
}

fn main() {
    println!("=== dalek::app — allreduce loop, rank count x fabric load ===\n");
    println!(
        "{PARTITION} (5 GbE), {ITERS} iterations of ({WORK_S:.0} s compute + \
         {} MB allreduce); background = frontend bulk pulls into the same nodes\n",
        GRAD_BYTES / 1_000_000
    );

    let mut t = Table::new(&[
        "ranks",
        "bg flows",
        "makespan (s)",
        "job energy (kJ)",
        "collective (GB)",
        "sim wall (s)",
    ])
    .title("BSP barrier under contention")
    .left(0);
    for ranks in [2u32, 3, 4] {
        for bg in [0u32, 2, 4, 8] {
            let r = run(ranks, bg);
            t.row(&[
                ranks.to_string(),
                bg.to_string(),
                format!("{:.1}", r.makespan_s),
                format!("{:.1}", r.job_energy_j / 1e3),
                format!("{:.2}", r.collective_bytes / 1e9),
                format!("{:.3}", r.wall_s),
            ]);
        }
    }
    t.print();
    println!();

    // event-rate overhead: the contended 4-rank case, timed
    let r = benchkit::bench("appmodel/replay(4 ranks, 4 bg flows)", 1, 5, || {
        let o = run(4, 4);
        std::hint::black_box(o.makespan_s);
    });
    println!(
        "simulated-hour speedup vs wall clock: {:.0}x\n",
        3600.0 / (r.summary.p50 / 1e9)
    );
}
