//! Bench: regenerate paper Table 2 (resource & power accounting) and
//! verify the Total row against the paper's printed values.

use dalek::bench::tables;
use dalek::hw::Catalog;
use dalek::util::benchkit;

fn main() {
    println!("=== Table 2 — resources & power ===\n");
    let catalog = Catalog::dalek();
    tables::table2(&catalog).print();

    let total = catalog.account_total();
    let checks = [
        ("nodes", total.nodes as f64, 21.0),
        ("cpu cores", total.cpu_cores as f64, 270.0),
        ("cpu threads", total.cpu_threads as f64, 476.0),
        ("ram GB", total.ram_gb as f64, 1136.0),
        ("iGPU cores", total.igpu_cores as f64, 9984.0),
        ("dGPU cores", total.dgpu_cores as f64, 106_496.0),
        ("VRAM GB", total.vram_gb as f64, 256.0),
        ("idle W", total.idle_w, 727.0),
        ("suspend W", total.suspend_w, 112.0),
        ("TDP W", total.tdp_w, 5427.0),
    ];
    println!("\npaper-vs-model Total row:");
    for (name, got, want) in checks {
        let ok = (got - want).abs() < 1e-9;
        println!("  {name:<12} model={got:<9} paper={want:<9} {}", if ok { "OK" } else { "MISMATCH" });
        assert!(ok, "{name}");
    }
    println!("\n--- accounting timing ---");
    benchkit::bench("tab2/account_total", 10, 200, || {
        let c = Catalog::dalek();
        std::hint::black_box(c.account_total());
    });
}
