//! Bench: the fair-share hot paths — the per-partition priority sort
//! under a 1k-tenant skewed-share population, deficit bookkeeping at
//! settlement rate, and the preempt/requeue churn the margin allows.
//! The machine-readable twin (`dalek bench perf`, case `fairshare`)
//! feeds the committed `BENCH_fairshare.json` regression baseline.

use dalek::config::ClusterConfig;
use dalek::power::Activity;
use dalek::sim::SimTime;
use dalek::slurm::{FairShareDb, JobSpec, SlurmSim};
use dalek::util::benchkit;

/// `n` single-to-3-node jobs from `users` tenants at ~4x cluster
/// capacity: the queue stays deep, so every pass sorts real backlog.
fn skewed_storm(users: u64, n: u64) -> Vec<(SimTime, JobSpec)> {
    (0..n)
        .map(|i| {
            let part = ["az4-n4090", "az4-a7900", "iml-ia770", "az5-a890m"][(i % 4) as usize];
            let spec = JobSpec {
                user: format!("u{}", i % users),
                partition: part.into(),
                nodes: 1 + (i % 3) as u32,
                duration: SimTime::from_secs(90 + (i % 11) * 30),
                time_limit: SimTime::from_mins(60),
                payload: None,
                activity: Activity::cpu_only(0.9),
                app: None,
            };
            (SimTime::from_secs(i * 11), spec)
        })
        .collect()
}

fn run(users: u64, jobs: &[(SimTime, JobSpec)]) -> SlurmSim {
    let mut s = SlurmSim::from_config(&ClusterConfig::dalek_default());
    for u in 0..users {
        s.ctl.fairshare.set_share(&format!("u{u}"), 1.0 + (u % 37) as f64);
    }
    for (at, spec) in jobs {
        s.submit_at(spec.clone(), *at).expect("valid");
    }
    s.run_to_idle();
    s
}

fn main() {
    println!("=== fair-share / preemption hot paths ===\n");

    let (users, n) = (1_000u64, 6_000u64);
    let jobs = skewed_storm(users, n);
    let r = benchkit::bench("fairshare/storm(1k tenants, 6k jobs, preempt ON)", 1, 3, || {
        let s = run(users, &jobs);
        assert_eq!(s.stats.completed, n);
        std::hint::black_box(s.stats.preemptions);
    });
    let s = run(users, &jobs);
    println!(
        "jobs/s: {:.0}   preemptions: {}   settled units: {:.3e}\n",
        benchkit::per_sec(&r, n as f64),
        s.stats.preemptions,
        s.ctl
            .fairshare
            .accounts()
            .map(|(_, a)| a.usage)
            .sum::<f64>(),
    );

    // the ledger alone: reserve/settle cycles at queue rate, no sim —
    // pins the cost of the exact-once bookkeeping itself
    benchkit::bench("fairshare/ledger(100k reserve+settle cycles)", 2, 10, || {
        use dalek::slurm::JobId;
        let mut db = FairShareDb::default();
        for u in 0..1_000u64 {
            db.set_share(&format!("u{u}"), 1.0 + (u % 37) as f64);
        }
        let mut acc = 0.0f64;
        for i in 0..100_000u64 {
            let user = format!("u{}", i % 1_000);
            db.reserve(JobId(i), &user, 600.0);
            db.settle(JobId(i), &user, 120.0, 9_000.0);
            acc += db.user_priority(&user);
        }
        std::hint::black_box(acc);
    });
}
