//! Bench: the streaming multi-client API under load.
//!
//! Two timings:
//!
//! 1. **Request throughput** — a seeded 8-client `TraceGen::client_storm`
//!    (srun tickets, subscriptions, polls, admin ops) replayed through
//!    the deterministic `ApiServer` multiplexer: requests served per
//!    wall-second, round-robin fairness and rate limits included.
//! 2. **Telemetry decimation** — one session watching a governor-capped
//!    hour at 10 Hz through a `Telemetry` subscription in an *unsampled*
//!    run: the windows are cut from the rolling piecewise history in
//!    closed form, so the events must arrive without a single probe
//!    sample being materialized (asserted), and wall time must track
//!    the number of power changes, not the simulated seconds.

use dalek::api::{ApiServer, Channel, ClusterApi, Event};
use dalek::config::ClusterConfig;
use dalek::coordinator::trace::TraceGen;
use dalek::sim::SimTime;
use dalek::util::benchkit;

const CLIENTS: usize = 8;
const REQUESTS: usize = 400;
const SEED: u64 = 0xDA1EC;

fn storm_server() -> (ApiServer, Vec<dalek::coordinator::trace::StormEvent>) {
    let cluster = ClusterApi::new(ClusterConfig::dalek_default(), None).expect("cluster");
    let mut server = ApiServer::new(cluster);
    server.connect("root").expect("root session");
    for k in 1..CLIENTS {
        server.connect(&format!("user{k}")).expect("user session");
    }
    let mut gen = TraceGen::dalek_mix(SEED);
    gen.jobs_per_hour = 1200.0; // an arrival every ~3 s
    let storm = gen.client_storm(CLIENTS, REQUESTS);
    (server, storm)
}

fn main() {
    println!("=== streaming api — multi-client storms + telemetry ===\n");

    // correctness anchor: the storm is deterministic before it is fast
    let digest = {
        let (mut server, storm) = storm_server();
        server.run_storm(&storm);
        let settle = server.cluster.now() + SimTime::from_mins(30);
        server.settle(settle);
        server.transcript_digest()
    };
    let digest2 = {
        let (mut server, storm) = storm_server();
        server.run_storm(&storm);
        let settle = server.cluster.now() + SimTime::from_mins(30);
        server.settle(settle);
        server.transcript_digest()
    };
    assert_eq!(digest, digest2, "storm replay must be bit-identical");

    let r = benchkit::bench(
        &format!("api/storm({CLIENTS} clients, {REQUESTS} reqs)"),
        1,
        5,
        || {
            let (mut server, storm) = storm_server();
            server.run_storm(&storm);
            let settle = server.cluster.now() + SimTime::from_mins(30);
            server.settle(settle);
            std::hint::black_box(server.transcript_digest().len());
        },
    );
    let wall_s = r.summary.p50 / 1e9;
    println!(
        "{}\n  requests/s: {:.0}\n",
        r.report(),
        REQUESTS as f64 / wall_s
    );

    // telemetry decimation over a governor-capped simulated hour,
    // entirely unsampled
    let run_telemetry = || {
        let mut c = ClusterApi::new(ClusterConfig::dalek_default(), None).expect("cluster");
        let root = c.login("root").expect("root");
        c.set_outbox_capacity(100_000);
        c.subscribe(root, Channel::Telemetry, Some(10.0)).expect("subscribe");
        c.set_power_budget(root, Some(400.0)).expect("budget");
        let mut gen = TraceGen::powercap_mix(SEED);
        for ev in gen.generate(40) {
            c.submit(ev.spec.clone(), ev.at).expect("valid trace");
        }
        c.run_until(SimTime::from_hours(1), false);
        let events = c.take_events(root, usize::MAX);
        let windows = events
            .iter()
            .filter(|e| matches!(e, Event::Telemetry { .. }))
            .count();
        assert_eq!(
            c.report().samples,
            0,
            "telemetry must not materialize samples"
        );
        windows
    };
    let windows = run_telemetry();
    assert_eq!(windows, 36_000, "10 Hz x 3600 s");
    let r = benchkit::bench("api/telemetry(10 Hz, capped hour, unsampled)", 1, 5, || {
        std::hint::black_box(run_telemetry());
    });
    let wall_s = r.summary.p50 / 1e9;
    println!(
        "{}\n  windows delivered: {windows}   windows/s: {:.0} k\n",
        r.report(),
        windows as f64 / wall_s / 1e3
    );
}
