//! Bench: the end-to-end stack (experiment E2E) — trace replay with and
//! without 1 kSPS energy sampling, plus the PJRT payload path when
//! artifacts are available.

use dalek::config::ClusterConfig;
use dalek::coordinator::{trace, Cluster};
use dalek::util::benchkit;

fn main() {
    println!("=== end-to-end cluster replay ===\n");

    let make_trace = |n: usize| {
        let mut gen = trace::TraceGen::dalek_mix(0xE2E);
        gen.payloads.clear();
        gen.generate(n)
    };

    let tr = make_trace(100);
    let r = benchkit::bench("e2e/replay(100 jobs, sampling OFF)", 1, 10, || {
        let mut c = Cluster::new(ClusterConfig::dalek_default(), None).expect("cluster");
        let rep = trace::replay(&mut c, &tr, false);
        assert_eq!(rep.completed + rep.timeouts, 100);
        std::hint::black_box(rep.true_energy_j);
    });
    let sim_secs = {
        let mut c = Cluster::new(ClusterConfig::dalek_default(), None).expect("cluster");
        trace::replay(&mut c, &tr, false).makespan.as_secs_f64()
    };
    println!(
        "simulated {:.1} h of cluster time; speedup {:.0}x\n",
        sim_secs / 3600.0,
        sim_secs / (r.summary.p50 / 1e9)
    );

    let tr20 = make_trace(20);
    let r = benchkit::bench("e2e/replay(20 jobs, sampling ON @1 kSPS x16 nodes)", 1, 3, || {
        let mut c = Cluster::new(ClusterConfig::dalek_default(), None).expect("cluster");
        let rep = trace::replay(&mut c, &tr20, true);
        std::hint::black_box(rep.measured_energy_j);
    });
    let (samples, sim_secs) = {
        let mut c = Cluster::new(ClusterConfig::dalek_default(), None).expect("cluster");
        let rep = trace::replay(&mut c, &tr20, true);
        (c.report().samples, rep.makespan.as_secs_f64())
    };
    println!(
        "probe samples generated: {:.1} M over {:.1} h sim; samples/s: {:.1} M\n",
        samples as f64 / 1e6,
        sim_secs / 3600.0,
        benchkit::per_sec(&r, samples as f64) / 1e6
    );

    // PJRT payload path (only when `make artifacts` has run)
    let dir = "artifacts";
    if std::path::Path::new(dir).join("manifest.json").exists() {
        let mut rt = dalek::runtime::PjRtRuntime::load(dir).expect("runtime");
        rt.compile("gemm256").expect("compile");
        let r = benchkit::bench("pjrt/execute(gemm256, 2*256^3 FLOP)", 3, 30, || {
            let rep = rt.execute("gemm256", 1).expect("exec");
            std::hint::black_box(rep.output_sum);
        });
        println!(
            "achieved on host CPU: {:.2} GFLOP/s",
            2.0 * 256.0f64.powi(3) / (r.summary.p50 / 1e9) / 1e9
        );
        let r = benchkit::bench("pjrt/execute(cnn_small fwd, batch 8)", 3, 30, || {
            let rep = rt.execute("cnn_small", 1).expect("exec");
            std::hint::black_box(rep.output_sum);
        });
        println!(
            "CNN images/s: {:.0}",
            benchkit::per_sec(&r, 8.0)
        );
    } else {
        println!("(artifacts missing — PJRT payload benches skipped; run `make artifacts`)");
    }
}
