//! Bench: regenerate paper Fig. 7 (GPU peak op/s per dtype, clpeak).

use dalek::bench::clpeak;
use dalek::util::benchkit;

fn main() {
    println!("=== Fig. 7 — GPU peak op/s (clpeak mad kernels) ===\n");
    clpeak::render_ops(&clpeak::run_all_ops(0xDA1EC, true)).print();
    println!("\n--- executor timing ---");
    benchkit::bench("fig7/run_all(7 GPUs x 6 dtypes)", 3, 100, || {
        let p = clpeak::run_all_ops(1, true);
        std::hint::black_box(p.len());
    });
}
