//! Bench: streaming segment-batched energy sampling throughput.
//!
//! The perf trajectory seed for the kernel refactor: replay a
//! 24-simulated-hour, idle-heavy trace (the §3.4 sweet spot — long
//! constant-power stretches) with 1 kSPS × 16-node sampling ON, and
//! report wall time plus generated samples per wall-second.
//!
//! Pre-refactor, `run_until(sample = true)` replayed cloned per-node
//! power histories through the per-conversion probe loop:
//! O(simulated-seconds × probes × 4 kSPS) ≈ 5.5 G conversions for this
//! trace, regardless of how little actually happened. The streaming
//! sampler's cost is proportional to power *changes* (a few hundred
//! here), so the 1.38 G generated samples cost a few closed-form
//! batches per segment plus ring materialization.

use dalek::config::ClusterConfig;
use dalek::coordinator::{trace, Cluster};
use dalek::sim::SimTime;
use dalek::util::benchkit;

fn main() {
    println!("=== streaming sampler — 24 h idle-heavy trace ===\n");

    // ~12 short jobs across the day: the cluster is suspended or idle
    // for the overwhelming majority of the 24 h window
    let make_trace = || {
        let mut gen = trace::TraceGen::dalek_mix(0x5A9);
        gen.payloads.clear();
        gen.jobs_per_hour = 0.5;
        gen.generate(12)
    };
    let tr = make_trace();
    let day = SimTime::from_hours(24);

    let run = |sample: bool| {
        let mut c = Cluster::new(ClusterConfig::dalek_default(), None).expect("cluster");
        for ev in &tr {
            c.submit(ev.spec.clone(), ev.at).expect("valid trace");
        }
        c.run_until(day, sample);
        c.report()
    };

    // correctness anchor before timing: measured tracks truth
    let rep = run(true);
    assert!(rep.samples > 1_000_000_000, "expected ≥1 G samples over 24 h");
    let rel = (rep.measured_energy_j - rep.true_energy_j).abs() / rep.true_energy_j;
    assert!(rel < 0.01, "measured energy off by {rel}");

    let r = benchkit::bench("sampling/replay(24 h, 16 nodes, 1 kSPS, ON)", 1, 5, || {
        let rep = run(true);
        std::hint::black_box(rep.measured_energy_j);
    });
    let wall_s = r.summary.p50 / 1e9;
    println!(
        "samples generated: {:.2} G over {:.0} h sim   wall p50: {}   samples/s: {:.1} M",
        rep.samples as f64 / 1e9,
        day.as_secs_f64() / 3600.0,
        dalek::util::units::secs(wall_s),
        rep.samples as f64 / wall_s / 1e6,
    );

    let r_off = benchkit::bench("sampling/replay(24 h, 16 nodes, OFF)", 1, 5, || {
        let rep = run(false);
        std::hint::black_box(rep.true_energy_j);
    });
    println!(
        "sampling overhead over unsampled replay: {:.2}x\n",
        r.summary.p50 / r_off.summary.p50
    );
}
