//! Bench: regenerate paper Fig. 8 (GPU kernel-launch latency) — 10k
//! simulated launches per GPU, jitter + tail modeling included.

use dalek::bench::latency;
use dalek::util::benchkit;

fn main() {
    println!("=== Fig. 8 — GPU kernel launch latency (OpenCL) ===\n");
    latency::render(&latency::run_all(0xDA1EC, 10_000)).print();
    println!("\n--- executor timing ---");
    let r = benchkit::bench("fig8/run_all(7 GPUs x 10k launches)", 2, 20, || {
        let p = latency::run_all(1, 10_000);
        std::hint::black_box(p.len());
    });
    println!(
        "simulated launches/s: {:.0}",
        benchkit::per_sec(&r, 5.0 * 10_000.0)
    );
}
