//! Bench: regenerate paper Fig. 5 (CPU peak op/s, cpufp).

use dalek::bench::cpufp;
use dalek::util::benchkit;

fn main() {
    println!("=== Fig. 5 — CPU peak performance (cpufp) ===\n");
    let points = cpufp::run_all(0xDA1EC, true);
    for m in cpufp::Mode::ALL {
        cpufp::render(&points, m).print();
        println!();
    }
    println!("--- executor timing ---");
    benchkit::bench("fig5/run_all(4 CPUs x 4 instrs x 3 modes)", 3, 50, || {
        let p = cpufp::run_all(1, true);
        std::hint::black_box(p.len());
    });
}
