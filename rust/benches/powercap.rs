//! Bench: the §3.6 power-cap governor under shrinking cluster budgets.
//!
//! Replays the dense GPU-heavy `powercap_mix` trace at several budget
//! levels (fractions of the cluster's full-load draw) and prints the
//! energy/makespan trade-off the governor buys: lower budgets cost wall
//! time, save energy, and must never kill a job. Also times the replay
//! itself — the governor's 1 Hz control tick must not make simulation
//! wall time blow up.

use dalek::api::ClusterApi;
use dalek::config::cluster::resolve_partition;
use dalek::config::ClusterConfig;
use dalek::coordinator::trace::{replay, TraceGen};
use dalek::power::{Activity, PowerModel};
use dalek::util::{benchkit, Table};

const JOBS: usize = 60;
const SEED: u64 = 0xCAB;

struct Outcome {
    completed: u64,
    makespan_s: f64,
    energy_j: f64,
    mean_w: f64,
}

/// Full-load cluster draw (all 16 nodes busy at peak activity) — the
/// reference the budget fractions scale.
fn full_load_w(cfg: &ClusterConfig) -> f64 {
    cfg.partitions
        .iter()
        .map(|pc| {
            let node = resolve_partition(&pc.name).expect("known partition").node;
            let act = Activity {
                cpu: 1.0,
                dgpu: if node.dgpu.is_some() { 1.0 } else { 0.0 },
                igpu: 0.0,
            };
            PowerModel::for_node(&node).watts(act) * pc.nodes as f64
        })
        .sum()
}

fn run_at(budget_w: Option<f64>) -> (Outcome, f64) {
    let mut cluster = ClusterApi::new(ClusterConfig::dalek_default(), None).expect("cluster");
    if let Some(w) = budget_w {
        let sid = cluster.login("root").expect("root");
        cluster.set_power_budget(sid, Some(w)).expect("admin");
    }
    let mut gen = TraceGen::powercap_mix(SEED);
    let tr = gen.generate(JOBS);
    let t0 = std::time::Instant::now();
    let report = replay(&mut cluster, &tr, false);
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(
        report.completed + report.timeouts,
        JOBS as u64,
        "governor must never kill a job"
    );
    (
        Outcome {
            completed: report.completed,
            makespan_s: report.makespan.as_secs_f64(),
            energy_j: report.true_energy_j,
            mean_w: report.mean_cluster_w,
        },
        wall,
    )
}

fn main() {
    println!("=== §3.6 power-cap governor: energy vs makespan ===\n");
    let cfg = ClusterConfig::dalek_default();
    let full = full_load_w(&cfg);
    println!("full-load reference draw: {full:.0} W\n");

    let mut t = Table::new(&[
        "budget",
        "watts",
        "completed",
        "makespan (s)",
        "energy (kJ)",
        "mean W",
        "sim wall (s)",
    ])
    .title("powercap_mix, 60 jobs, seed 0xCAB")
    .left(0);
    for (label, frac) in [
        ("uncapped", None),
        ("80%", Some(0.8)),
        ("60%", Some(0.6)),
        ("40%", Some(0.4)),
    ] {
        let budget = frac.map(|f: f64| f * full);
        let (r, wall) = run_at(budget);
        t.row(&[
            label.to_string(),
            budget
                .map(|b| format!("{b:.0}"))
                .unwrap_or_else(|| "—".into()),
            r.completed.to_string(),
            format!("{:.0}", r.makespan_s),
            format!("{:.1}", r.energy_j / 1e3),
            format!("{:.0}", r.mean_w),
            format!("{wall:.3}"),
        ]);
    }
    t.print();
    println!();

    // control-tick overhead: the budgeted replay of the same trace, timed
    let r = benchkit::bench("powercap/replay(60 jobs, 60% budget)", 1, 5, || {
        let (r, _) = run_at(Some(0.6 * full));
        std::hint::black_box(r.energy_j);
    });
    println!(
        "simulated-hour speedup vs wall clock: {:.0}x\n",
        3600.0 / (r.summary.p50 / 1e9)
    );
}
