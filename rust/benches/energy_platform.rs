//! Bench: the §4 energy measurement platform — sampling-rate knee
//! (1000 SPS × 6 probes per I2C chain) and the sample-path hot loop
//! (the perf target: tens of millions of generated samples per second,
//! so day-long 1 kSPS cluster traces simulate in seconds).

use dalek::energy::bus::I2cBus;
use dalek::energy::{Ina228Probe, ProbeConfig};
use dalek::sim::SimTime;
use dalek::util::{benchkit, Table, Xoshiro256};

fn main() {
    println!("=== §4 — energy measurement platform ===\n");

    // the paper's arbitration table: effective SPS vs probes on a chain
    let mut t = Table::new(&["probes", "req 1000 SPS", "req 2000 SPS", "req 4000 SPS"])
        .title("effective per-probe SPS after I2C arbitration");
    for n in 1..=6usize {
        let mut bus = I2cBus::new();
        for i in 0..n {
            bus.attach(i as u8).expect("≤6");
        }
        t.row(&[
            n.to_string(),
            format!("{:.0}", bus.effective_sps(1000.0)),
            format!("{:.0}", bus.effective_sps(2000.0)),
            format!("{:.0}", bus.effective_sps(4000.0)),
        ]);
    }
    t.print();

    // resolution check: mW quantization on a known signal
    let mut probe = Ina228Probe::new(0, ProbeConfig::default(), Xoshiro256::new(7));
    let samples = probe.sample_until(&|_t: SimTime| 123.4567, SimTime::from_secs(1), 0);
    let mean: f64 = samples.iter().map(|s| s.power_w).sum::<f64>() / samples.len() as f64;
    println!(
        "\n1 s @ 123.4567 W: {} samples, mean {:.4} W (err {:+.2} mW), all mW-quantized",
        samples.len(),
        mean,
        (mean - 123.4567) * 1e3
    );

    println!("\n--- sample-path timing ---");
    let r = benchkit::bench("probe/sample_until(1 s @ 1000 SPS)", 3, 50, || {
        let mut p = Ina228Probe::new(0, ProbeConfig::default(), Xoshiro256::new(1));
        let s = p.sample_until(&|_t: SimTime| 100.0, SimTime::from_secs(1), 0);
        std::hint::black_box(s.len());
    });
    // 4000 ADC conversions -> 1000 samples per iteration
    println!(
        "ADC conversions/s: {:.2} M   reported samples/s: {:.2} M",
        benchkit::per_sec(&r, 4000.0) / 1e6,
        benchkit::per_sec(&r, 1000.0) / 1e6
    );

    let r = benchkit::bench("probe/sample_until(60 s @ 1000 SPS)", 1, 10, || {
        let mut p = Ina228Probe::new(0, ProbeConfig::default(), Xoshiro256::new(1));
        let s = p.sample_until(&|_t: SimTime| 100.0, SimTime::from_secs(60), 0);
        std::hint::black_box(s.len());
    });
    println!(
        "sustained reported samples/s: {:.2} M",
        benchkit::per_sec(&r, 60_000.0) / 1e6
    );
}
