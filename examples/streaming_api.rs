//! Streaming API — many clients watching one cluster live.
//!
//! The §4 energy platform exists to be watched: this example stands up
//! the deterministic `ApiServer` multiplexer with four concurrent
//! sessions — an operator streaming the governor's `PowerEvents`, a
//! telemetry dashboard decimating the measured draw at 2 Hz, and two
//! users firing nonblocking srun tickets and following their jobs
//! through `JobEvents` — then replays a seeded request storm and prints
//! what each client saw. Re-running prints the identical transcript:
//! the whole multi-client exchange is reproducible bit-for-bit.
//!
//! Run: `cargo run --release --example streaming_api`

use dalek::api::{ApiServer, Channel, ClusterApi, JobRequest, Request};
use dalek::config::ClusterConfig;
use dalek::coordinator::trace::TraceGen;
use dalek::sim::SimTime;
use dalek::util::units;

fn job(partition: &str, nodes: u32, secs: u64) -> JobRequest {
    JobRequest {
        partition: partition.into(),
        nodes,
        duration: SimTime::from_secs(secs),
        time_limit: None,
        payload: None,
        iters: 1,
        user: None,
        app: None,
    }
}

fn main() -> anyhow::Result<()> {
    println!("== DALEK streaming API: tickets, subscriptions, one deterministic server ==\n");

    let cluster = ClusterApi::new(ClusterConfig::dalek_default(), None)?;
    let mut server = ApiServer::new(cluster);
    let operator = server.connect("root")?;
    let dashboard = server.connect("grafana")?;
    let alice = server.connect("alice")?;
    let bob = server.connect("bob")?;

    // the operator arms a 500 W budget and watches the control plane
    server.enqueue(
        operator,
        Request::SetPowerBudget { watts: Some(500.0) },
    );
    server.enqueue(
        operator,
        Request::Subscribe {
            channel: Channel::PowerEvents,
            rate_hz: None,
            expr: None,
        },
    );
    // the dashboard decimates cluster telemetry at 2 Hz — no samples
    // are materialized for this, it is cut from the rolling segments
    server.enqueue(
        dashboard,
        Request::Subscribe {
            channel: Channel::Telemetry,
            rate_hz: Some(2.0),
            expr: None,
        },
    );
    // users follow their own jobs; srun no longer blocks anyone
    for client in [alice, bob] {
        server.enqueue(
            client,
            Request::Subscribe {
                channel: Channel::JobEvents,
                rate_hz: None,
                expr: None,
            },
        );
    }
    server.enqueue(alice, Request::RunJob(job("az5-a890m", 4, 300)));
    server.enqueue(bob, Request::RunJob(job("az4-a7900", 2, 180)));
    server.enqueue(bob, Request::SubmitJob(job("iml-ia770", 1, 120)));
    server.drain();
    println!(
        "8 requests served round-robin; backlog {} — tickets issued, nobody blocked\n",
        server.backlog()
    );

    // a seeded background storm from all four clients
    let mut gen = TraceGen::dalek_mix(0x57A6);
    gen.jobs_per_hour = 900.0;
    let storm = gen.client_storm(4, 60);
    server.run_storm(&storm);
    let settle = server.cluster.now() + SimTime::from_mins(30);
    server.settle(settle);

    let names = ["operator", "dashboard", "alice", "bob"];
    for (ci, name) in names.iter().enumerate() {
        let c = server.client(ci);
        println!(
            "{name:<9}  {} requests served, {} transcript lines",
            c.served,
            c.transcript.len()
        );
    }
    println!();

    // what the streams carried (settle() already drained them into the
    // transcripts; show the operator's view of the storm)
    let mut ticks = 0usize;
    let mut caps = 0usize;
    let mut windows = 0usize;
    let mut job_events = 0usize;
    for ci in 0..4 {
        for line in &server.client(ci).transcript {
            ticks += line.matches("\"kind\":\"governor_tick\"").count();
            caps += line.matches("\"kind\":\"cap_actuated\"").count();
            windows += line.matches("\"event\":\"telemetry\"").count();
            job_events += line.matches("\"event\":\"job\"").count();
        }
    }
    println!("delivered over the event plane:");
    println!("  governor ticks     {ticks}");
    println!("  cap actuations     {caps}");
    println!("  telemetry windows  {windows}");
    println!("  job lifecycle      {job_events}");

    let r = server.cluster.report();
    println!(
        "\ncluster after {}: {} jobs completed, {} true energy, 0 samples materialized",
        units::secs(r.now.as_secs_f64()),
        r.jobs_completed,
        units::joules(r.true_energy_j),
    );
    assert_eq!(r.samples, 0);
    Ok(())
}
