//! Quickstart — the end-to-end driver.
//!
//! Builds the full DALEK cluster (paper topology), loads the AOT
//! artifacts if present, replays a 200-job mixed trace (CPU jobs + real
//! PJRT payload jobs across all four partitions) with the §4 energy
//! platform sampling at 1000 SPS, and prints the headline report:
//! throughput, waiting times, utilization, true vs probe-measured
//! energy. This is experiment E2E of DESIGN.md.
//!
//! Everything goes through the session-based `dalek::api` surface: the
//! replay drives `ClusterApi` (the coordinator's `Cluster` façade), and
//! the tail of the example shows the same cluster queried as a user —
//! login, sample retrieval, and a raw JSON protocol round trip.
//!
//! Run: `cargo run --release --example quickstart`

use dalek::api::Request;
use dalek::config::ClusterConfig;
use dalek::coordinator::{trace, Cluster};
use dalek::sim::SimTime;
use dalek::slurm::JobState;
use dalek::util::{units, Table};

fn main() -> anyhow::Result<()> {
    let artifact_dir = "artifacts";
    let have_artifacts = std::path::Path::new(artifact_dir)
        .join("manifest.json")
        .exists();

    println!("== DALEK quickstart: 200-job mixed trace on the paper topology ==\n");
    let cfg = ClusterConfig::dalek_default();
    println!(
        "cluster `{}`: {} partitions, {} compute nodes, suspend after {}",
        cfg.name,
        cfg.partitions.len(),
        cfg.total_nodes(),
        units::secs(cfg.power.suspend_after.as_secs_f64()),
    );
    let mut cluster = Cluster::new(cfg, have_artifacts.then_some(artifact_dir))?;
    if let Some(rt) = cluster.runtime() {
        println!(
            "PJRT runtime up (platform = {}), payloads: {:?}",
            rt.platform(),
            rt.payload_names()
        );
    } else {
        println!("note: artifacts/ missing — run `make artifacts`; using synthetic jobs only");
    }

    let mut gen = trace::TraceGen::dalek_mix(0xDA1EC);
    if !cluster.has_runtime() {
        gen.payloads.clear();
    }
    let tr = gen.generate(200);
    println!("\nreplaying {} jobs (energy sampling ON, 1000 SPS/node)…", tr.len());
    let t0 = std::time::Instant::now();
    let report = trace::replay(&mut cluster, &tr, true);
    let wall = t0.elapsed().as_secs_f64();

    let mut t = Table::new(&["metric", "value"]).title("E2E report").left(0).left(1);
    t.row_strs(&["jobs", &report.jobs.to_string()]);
    t.row_strs(&["completed", &report.completed.to_string()]);
    t.row_strs(&["timeouts", &report.timeouts.to_string()]);
    t.row_strs(&["simulated makespan", &units::secs(report.makespan.as_secs_f64())]);
    if let Some(w) = &report.wait {
        t.row_strs(&[
            "queue wait p50 / p95 / max",
            &format!(
                "{} / {} / {}",
                units::secs(w.p50),
                units::secs(w.p95),
                units::secs(w.max)
            ),
        ]);
    }
    t.row_strs(&["throughput", &format!("{:.1} jobs/h", report.throughput_jobs_per_hour)]);
    t.row_strs(&["true energy (scheduler integration)", &units::joules(report.true_energy_j)]);
    t.row_strs(&["measured energy (§4 probes @1 kSPS)", &units::joules(report.measured_energy_j)]);
    let err = (report.measured_energy_j - report.true_energy_j).abs()
        / report.true_energy_j.max(1e-9)
        * 100.0;
    t.row_strs(&["probe vs truth", &format!("{err:.3} %")]);
    t.row_strs(&["mean cluster draw", &units::watts(report.mean_cluster_w)]);
    t.row_strs(&["host wall-clock for the replay", &units::secs(wall)]);
    t.print();

    // per-partition node accounting (boots/suspends prove §3.4 works)
    let mut nt = Table::new(&["node", "state", "boots", "suspends", "energy"])
        .title("\nper-node accounting (first node of each partition)")
        .left(0)
        .left(1);
    for info in cluster.slurm().node_infos().iter().filter(|n| n.name.ends_with("-0")) {
        nt.row(&[
            info.name.clone(),
            format!("{:?}", info.state),
            info.boots.to_string(),
            info.suspends.to_string(),
            units::joules(info.energy_j),
        ]);
    }
    nt.print();

    let failed = cluster
        .slurm()
        .jobs()
        .filter(|j| !matches!(j.state, JobState::Completed | JobState::Timeout))
        .count();
    anyhow::ensure!(failed == 0, "{failed} jobs did not finish");

    // -- the same cluster, queried as a user through the session API --
    println!("\n== §4.3 user access: login once, query through the protocol ==");
    cluster.add_user("alice");
    let sid = cluster.login("alice")?;
    println!("alice logged in: {sid}");
    let now = cluster.now();
    let (total, kept) = cluster.samples(
        sid,
        "az4-n4090-0",
        0,
        (now.since(SimTime::from_secs(2)), now),
        100,
    )?;
    println!(
        "last 2 s of az4-n4090-0 probe 0: {total} samples in window, {} after 100x decimation",
        kept.len()
    );
    // and the raw JSON wire surface (what `dalek api` speaks):
    let wire = Request::QueryEnergy {
        node: None,
        window: None,
    }
    .to_json(Some(sid))
    .to_string();
    println!("request:  {wire}");
    let response = cluster.handle_json(&wire);
    println!("response: {response}");
    anyhow::ensure!(
        response.contains("\"ok\":true"),
        "authenticated wire request must succeed: {response}"
    );
    anyhow::ensure!(total > 0, "probe window must hold samples");

    // an unauthenticated request must bounce
    let denied = cluster.handle_json(r#"{"op": "cluster_report"}"#);
    anyhow::ensure!(denied.contains("\"ok\":false"), "no session, no service");

    println!("\nquickstart OK");
    Ok(())
}
