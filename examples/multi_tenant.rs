//! Multi-tenant fair-share on the DALEK rack: per-user shares, the
//! priority-aged queue, and preemption with a grace window.
//!
//! Act 1 — *allocation follows shares*: three tenants submit identical
//! backlogged demand, but hold a 6 : 3 : 1 share split. The measured
//! node allocation over a saturated two-hour window lands in share
//! order — the weighted deficit round-robin at work.
//!
//! Act 2 — *preemption with grace*: a low-share tenant camps on a full
//! partition; a high-share tenant's job arrives, outranks it past the
//! preemption margin, and evicts it after the 60 s grace window. The
//! victim's banked work resumes once the partition frees up — nothing
//! is lost, and the `JobEvents` channel narrates every step.
//!
//! The fair-share ledger is also a DQL surface:
//! `users.<user>.fairshare.{share, usage, priority}`.
//!
//! Run: `cargo run --release --example multi_tenant`

use dalek::api::{Channel, ClusterApi, Event, JobEventKind};
use dalek::config::ClusterConfig;
use dalek::query;
use dalek::sim::SimTime;
use dalek::slurm::{JobSpec, JobState};
use dalek::util::Table;

const TENANTS: [(&str, f64); 3] = [("alice", 6.0), ("bob", 3.0), ("carol", 1.0)];

/// A fresh cluster with the three tenants, their quotas and shares.
fn tenant_cluster() -> anyhow::Result<(ClusterApi, dalek::api::SessionId)> {
    let mut c = ClusterApi::new(ClusterConfig::dalek_default(), None)?;
    let root = c.login("root")?;
    c.subscribe(root, Channel::JobEvents, None)?;
    for (user, share) in TENANTS {
        c.add_user(user);
        c.set_quota(root, user, 1e9, 1e12)?;
        c.set_shares(root, user, share)?;
    }
    Ok((c, root))
}

fn main() -> anyhow::Result<()> {
    println!("== multi-tenant fair-share: shares, aging, preemption ==\n");

    // ---- act 1: equal demand, skewed shares ------------------------
    let (mut c, root) = tenant_cluster()?;
    let parts = ["az4-n4090", "az4-a7900", "iml-ia770", "az5-a890m"];
    // every tenant asks for ~9 sustained nodes of a 16-node rack: the
    // cluster is saturated and only the shares can arbitrate
    for (ui, (user, _)) in TENANTS.iter().enumerate() {
        let (mut t, mut i) = (7 * ui as u64, 0usize);
        while t < 7_200 {
            c.submit(JobSpec::cpu(user, parts[i % 4], 1, 180), SimTime::from_secs(t))?;
            t += 20;
            i += 1;
        }
    }
    // sample the running allocation once a minute past a warm-up
    let mut alloc = [0u64; 3];
    let mut now = SimTime::ZERO;
    while now < SimTime::from_hours(2) {
        now = now + SimTime::from_mins(1);
        c.run_until(now, false);
        if now >= SimTime::from_mins(20) {
            for j in c.slurm().jobs() {
                if j.state == JobState::Running {
                    if let Some(k) = TENANTS.iter().position(|(u, _)| *u == j.spec.user) {
                        alloc[k] += j.allocated.len() as u64;
                    }
                }
            }
        }
    }
    let total: u64 = alloc.iter().sum();
    let total_share: f64 = TENANTS.iter().map(|(_, s)| s).sum();
    let mut t = Table::new(&["tenant", "share", "share %", "allocated %"])
        .title("2 h saturated window, equal demand per tenant")
        .left(0);
    for (k, (user, share)) in TENANTS.iter().enumerate() {
        t.row(&[
            user.to_string(),
            format!("{share:.0}"),
            format!("{:.1}", 100.0 * share / total_share),
            format!("{:.1}", 100.0 * alloc[k] as f64 / total.max(1) as f64),
        ]);
    }
    t.print();
    anyhow::ensure!(
        alloc[0] > alloc[1] && alloc[1] > alloc[2],
        "allocation must land in share order under saturation"
    );
    c.take_events(root, usize::MAX); // act 1's stream is not the story

    // ---- act 2: preemption with a grace window ---------------------
    println!("\npreemption: carol camps on az4-n4090, alice outranks her\n");
    let (mut c, root) = tenant_cluster()?;
    let hog = c.submit(JobSpec::cpu("carol", "az4-n4090", 4, 1800), SimTime::ZERO)?;
    let vip = c.submit(JobSpec::cpu("alice", "az4-n4090", 4, 600), SimTime::from_secs(300))?;
    c.run_until(SimTime::from_hours(2), false);

    let mut preempted = 0u32;
    let mut resumed = 0u32;
    for e in c.take_events(root, usize::MAX) {
        if let Event::Job { at, job, kind } = e {
            let who = if job == hog { "carol/hog" } else { "alice/vip" };
            println!("  t={:7.0}s  {who:9}  {kind:?}", at.as_secs_f64());
            match kind {
                JobEventKind::Preempted => preempted += 1,
                JobEventKind::Resumed => resumed += 1,
                _ => {}
            }
        }
    }
    anyhow::ensure!(preempted >= 1, "the vip must preempt the hog");
    anyhow::ensure!(resumed >= 1, "the hog's banked work must resume");
    anyhow::ensure!(
        c.slurm().jobs().all(|j| j.state == JobState::Completed),
        "both jobs complete — preemption delays work, it never loses it"
    );
    let hj = c.slurm().job(hog).expect("exists");
    println!(
        "\nhog work ledger: {:.0} s of {:.0} s survived the eviction",
        hj.work_done_s, 1800.0
    );

    // ---- the ledger as a query surface -----------------------------
    for expr in ["users.carol.fairshare.priority", "sum(users.*.fairshare.usage)"] {
        let (canon, out) = c.query(root, expr)?;
        println!("dql {canon} = {}", query::output_json(&out));
    }

    println!("\nmulti_tenant OK");
    Ok(())
}
