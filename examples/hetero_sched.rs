//! Heterogeneous scheduling across core types — the Orhan et al. use
//! case (paper §6.1 "Heterogeneity": HCW'25, partially-replicable task
//! chains on two types of resources, validated on iml-ia770).
//!
//! The experiment: a chain of inference tasks (the mlp_infer payload)
//! must be mapped onto the Core Ultra 9 185H's p-cores and e-cores.
//! Three strategies are compared on makespan AND energy (the Idouar et
//! al. §6.1 extension: add real power to the scheduler evaluation):
//!   * p-only      — all tasks on the 6 p-cores
//!   * e-only      — all tasks on the 8 e-cores (+ 2 LPe)
//!   * greedy-hetero — earliest-finish-time across both pools
//!
//! Run: `cargo run --release --example hetero_sched`

use dalek::api::ClusterApi;
use dalek::config::ClusterConfig;
use dalek::hw::catalog::cpu_ultra9_185h;
use dalek::hw::cpu::{CoreClass, Instr};
use dalek::util::{units, Table};

/// One pool of identical workers.
#[derive(Clone, Debug)]
struct Pool {
    #[allow(dead_code)] // kept for debugging printouts
    label: &'static str,
    workers: u32,
    /// task execution time on one worker of this pool, seconds
    task_secs: f64,
    /// marginal power of one busy worker, watts
    worker_w: f64,
}

/// List-schedule `n` independent tasks over pools; returns (makespan s,
/// energy J) using earliest-finish-time assignment.
fn schedule(n: u64, pools: &[Pool]) -> (f64, f64) {
    // per-worker next-free time
    let mut free: Vec<(usize, f64)> = pools
        .iter()
        .enumerate()
        .flat_map(|(pi, p)| std::iter::repeat(pi).take(p.workers as usize).map(move |x| (x, 0.0)))
        .collect();
    let mut energy = 0.0;
    let mut makespan: f64 = 0.0;
    for _ in 0..n {
        // earliest finish time if assigned now
        let (idx, _) = free
            .iter()
            .enumerate()
            .map(|(i, (pi, t))| (i, t + pools[*pi].task_secs))
            .min_by(|a, b| a.1.partial_cmp(&b.1).expect("finite"))
            .expect("non-empty");
        let (pi, t) = free[idx];
        let fin = t + pools[pi].task_secs;
        energy += pools[pi].task_secs * pools[pi].worker_w;
        makespan = makespan.max(fin);
        free[idx] = (pi, fin);
    }
    (makespan, energy)
}

fn main() -> anyhow::Result<()> {
    println!("== heterogeneous task-chain scheduling on the Core Ultra 9 185H ==\n");
    let artifact_dir = "artifacts";
    anyhow::ensure!(
        std::path::Path::new(artifact_dir).join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    // ground the task cost: one mlp_infer call, real PJRT execution —
    // reached the way a user reaches it: log in, exec through the API
    let mut cluster = ClusterApi::new(ClusterConfig::dalek_default(), Some(artifact_dir))?;
    cluster.add_user("alice");
    let sid = cluster.login("alice")?;
    let exec = cluster.exec_payload(sid, "mlp_infer", 3, 3)?;
    println!(
        "real PJRT run (session {sid}): mlp_infer = {} / call ({})",
        units::secs(exec.wall_s),
        units::si(exec.flops_per_sec, "FLOP/s")
    );
    // task = 200 chained calls
    let task_flops = exec.flops as f64 * 200.0;

    let cpu = cpu_ultra9_185h();
    const ETA: f64 = 0.25;
    let per_core_secs = |class: CoreClass| {
        let cl = cpu.cluster(class).expect("exists");
        task_flops / (cl.peak_ops(Instr::FmaF32, 1) * ETA)
    };
    // marginal watts per busy core: split the CPU's dynamic budget by
    // class throughput share (p-cores burn disproportionately more)
    let p_w = 7.5;
    let e_w = 2.5;
    let lpe_w = 1.0;

    let p_pool = Pool {
        label: "p-cores",
        workers: 6,
        task_secs: per_core_secs(CoreClass::Performance),
        worker_w: p_w,
    };
    let e_pool = Pool {
        label: "e-cores",
        workers: 8,
        task_secs: per_core_secs(CoreClass::Efficient),
        worker_w: e_w,
    };
    let lpe_pool = Pool {
        label: "LPe-cores",
        workers: 2,
        task_secs: per_core_secs(CoreClass::LowPower),
        worker_w: lpe_w,
    };

    let n_tasks = 256u64;
    let strategies: Vec<(&str, Vec<Pool>)> = vec![
        ("p-only", vec![p_pool.clone()]),
        ("e-only", vec![e_pool.clone(), lpe_pool.clone()]),
        ("greedy-hetero", vec![p_pool, e_pool, lpe_pool]),
    ];

    let mut t = Table::new(&["strategy", "makespan", "energy", "J/task", "avg W"])
        .title(format!("{n_tasks} tasks of 200 mlp_infer calls each"))
        .left(0);
    let mut results = Vec::new();
    for (name, pools) in &strategies {
        let (mk, e) = schedule(n_tasks, pools);
        results.push((name.to_string(), mk, e));
        t.row(&[
            name.to_string(),
            units::secs(mk),
            units::joules(e),
            format!("{:.2}", e / n_tasks as f64),
            format!("{:.1}", e / mk),
        ]);
    }
    t.print();

    let hetero = results.iter().find(|(n, _, _)| n == "greedy-hetero").expect("ran");
    let p_only = results.iter().find(|(n, _, _)| n == "p-only").expect("ran");
    let e_only = results.iter().find(|(n, _, _)| n == "e-only").expect("ran");
    anyhow::ensure!(
        hetero.1 < p_only.1 && hetero.1 < e_only.1,
        "hetero must beat both homogeneous mappings on makespan"
    );
    anyhow::ensure!(
        e_only.2 < p_only.2,
        "e-cores must be the energy-optimal homogeneous choice"
    );
    println!(
        "\ngreedy-hetero is {:.1}% faster than p-only; e-only saves {:.1}% energy vs p-only \
         — the HCW'25 trade-off, now with the power axis.",
        (1.0 - hetero.1 / p_only.1) * 100.0,
        (1.0 - e_only.2 / p_only.2) * 100.0
    );
    println!("hetero_sched OK");
    Ok(())
}
