//! §3.3 — remote full-cluster reinstall over PXE.
//!
//! Drives the autoinstall pipeline for all sixteen compute nodes
//! concurrently through the flow-level network simulation (image fetch
//! from the frontend's 20 G uplink, per-MAC YAML, SSD unpack,
//! partition-specific driver late-commands) and reports the per-node
//! and total times against the paper's ≈20-minute claim. Also shows the
//! DHCP/DNS and NAT services doing their §3.2 jobs along the way.
//!
//! Run: `cargo run --release --example pxe_install`

use dalek::config::ClusterConfig;
use dalek::net::nat::FlowKey;
use dalek::net::{DhcpDns, Ipv4, NatTable, Topology};
use dalek::services::pxe::PxeInstaller;
use dalek::util::{units, Table};

fn main() -> anyhow::Result<()> {
    println!("== §3.3 PXE autoinstall of the full cluster ==\n");
    let cfg = ClusterConfig::dalek_default();
    let topo = Topology::build(&cfg);

    // §3.2: every node PXE-boots and gets its fixed lease by MAC
    let mut dhcp = DhcpDns::from_topology(&topo);
    println!("dnsmasq: {} fixed leases, domain `{}`", dhcp.fixed_lease_count(), dhcp.domain());
    for id in topo.compute_hosts() {
        let h = topo.host(id);
        let ip = dhcp.offer(h.mac).expect("fixed lease");
        assert_eq!(ip, h.ip, "MAC-keyed lease must match Table 3");
    }

    // §3.2: driver downloads from the internet ride the frontend NAT
    let mut nat = NatTable::new(Ipv4::new(132, 227, 77, 1));
    for id in topo.compute_hosts() {
        let h = topo.host(id);
        let (pub_ip, pub_port) = nat.outbound(FlowKey {
            src: h.ip,
            src_port: 50_000,
            dst: Ipv4::new(185, 125, 190, 36), // archive.ubuntu.com
            dst_port: 80,
        })?;
        assert_eq!(pub_ip, Ipv4::new(132, 227, 77, 1));
        let _ = pub_port;
    }
    println!("ufw NAT: {} translations active", nat.bindings());

    // the reinstall itself
    let installer = PxeInstaller::default();
    println!(
        "\nserving {} image + per-MAC YAML to 16 nodes over the 20 G uplink…",
        units::bytes(installer.image_bytes)
    );
    let hosts = topo.compute_hosts();
    let t0 = std::time::Instant::now();
    let reports = installer.reinstall_all(&topo, &hosts);
    let host_wall = t0.elapsed().as_secs_f64();

    let mut t = Table::new(&["node", "install time"])
        .title("per-node reinstall (concurrent)")
        .left(0);
    let mut worst = 0.0f64;
    for r in &reports {
        let d = r.finished.since(r.started).as_secs_f64();
        worst = worst.max(d);
        t.row(&[topo.host(r.host).name.clone(), units::secs(d)]);
    }
    t.print();

    println!(
        "\nfull reinstall: {} (paper: ≈20 min) — simulated in {}",
        units::secs(worst),
        units::secs(host_wall)
    );
    anyhow::ensure!(
        (12.0 * 60.0..28.0 * 60.0).contains(&worst),
        "reinstall time {worst}s out of the paper's ballpark"
    );
    println!("pxe_install OK");
    Ok(())
}
