//! Application-shaped workloads on the flow network — the `dalek::app`
//! phase/collective model, end to end.
//!
//! Three runs of the same CNN-training-like program (pull an NFS shard,
//! compute a step, ring-allreduce the gradients, repeat):
//!
//!   * solo       — one 4-rank app on iml-ia770 (5 GbE NICs), alone;
//!   * contended  — the same app while a second 4-rank app on
//!                  az4-n4090 pulls its own shards: both pull from the
//!                  frontend, whose 20 G uplink is exactly iml's
//!                  aggregate demand, so sharing strictly slows the
//!                  5 GbE app (§6.2's "saturates very quickly");
//!   * capped     — solo again under a cluster power budget: the §3.6
//!                  governor caps the ranks, compute phases stretch,
//!                  and the barrier waits for the repriced stragglers.
//!
//! Run: `cargo run --release --example app_workloads`

use dalek::api::ClusterApi;
use dalek::app::{AppSpec, Collective, PhaseSpec};
use dalek::config::ClusterConfig;
use dalek::sim::SimTime;
use dalek::slurm::{JobId, JobSpec, JobState};
use dalek::util::{units, Table};

/// shard each rank pulls per iteration
const SHARD: u64 = 1_000_000_000; // 1 GB at 5 GbE: 1.6 s solo
/// per-iteration compute per rank
const WORK_S: f64 = 15.0;
/// gradient buffer
const GRAD: u64 = 100_000_000;
const ITERS: u32 = 4;

fn training_app() -> AppSpec {
    AppSpec::new(
        "cnn-train",
        vec![
            PhaseSpec::Collective(Collective::NfsPull { bytes: SHARD }),
            PhaseSpec::Compute { work_s: WORK_S },
            PhaseSpec::Collective(Collective::Allreduce { bytes: GRAD }),
        ],
        ITERS,
    )
}

/// The NFS-heavy prototyping rival: pulls 4 GB shards with barely any
/// compute between them, so its frontend traffic overlaps every one of
/// the training app's I/O phases.
fn rival_app() -> AppSpec {
    AppSpec::new(
        "proto-nfs",
        vec![
            PhaseSpec::Collective(Collective::NfsPull { bytes: 4 * SHARD }),
            PhaseSpec::Compute { work_s: 1.0 },
        ],
        8,
    )
}

fn drain(c: &mut ClusterApi, id: JobId) -> f64 {
    let mut horizon = SimTime::from_mins(10);
    while !c.slurm().job(id).expect("submitted").is_terminal() {
        c.run_until(horizon, false);
        horizon += SimTime::from_mins(10);
        assert!(horizon < SimTime::from_hours(12), "app failed to drain");
    }
    let job = c.slurm().job(id).expect("submitted");
    assert_eq!(job.state, JobState::Completed);
    job.run_time().expect("terminal").as_secs_f64()
}

fn main() -> anyhow::Result<()> {
    println!("== dalek::app: phase-structured jobs on the 20 G frontend uplink ==\n");

    // solo: the 5 GbE app alone
    let mut c = ClusterApi::new(ClusterConfig::dalek_default(), None)?;
    let spec = JobSpec::app("root", "iml-ia770", training_app(), 4);
    let id = c.submit(spec, SimTime::ZERO)?;
    let solo_s = drain(&mut c, id);
    let solo_j = c.slurm().job(id).expect("done").energy_j;

    // contended: a second app's shard pulls share the frontend uplink
    let mut c = ClusterApi::new(ClusterConfig::dalek_default(), None)?;
    let spec = JobSpec::app("root", "iml-ia770", training_app(), 4);
    let id = c.submit(spec, SimTime::ZERO)?;
    let rival_spec = JobSpec::app("root", "az4-n4090", rival_app(), 4);
    let rival = c.submit(rival_spec, SimTime::ZERO)?;
    let cont_s = drain(&mut c, id);
    let _ = drain(&mut c, rival);
    let cont_j = c.slurm().job(id).expect("done").energy_j;
    let moved = c.apps().stats.collective_bytes;

    // capped: solo under a cluster power budget — compute stragglers
    let mut c = ClusterApi::new(ClusterConfig::dalek_default(), None)?;
    let sid = c.login("root")?;
    c.set_power_budget(sid, Some(250.0))?;
    let spec = JobSpec::app("root", "iml-ia770", training_app(), 4);
    let id = c.submit(spec, SimTime::ZERO)?;
    let capped_s = drain(&mut c, id);
    let capped_j = c.slurm().job(id).expect("done").energy_j;

    let mut t = Table::new(&["scenario", "run time", "job energy"]).left(0);
    t.row(&[
        "solo".into(),
        units::secs(solo_s),
        format!("{:.1} kJ", solo_j / 1e3),
    ]);
    t.row(&[
        "contended".into(),
        units::secs(cont_s),
        format!("{:.1} kJ", cont_j / 1e3),
    ]);
    t.row(&[
        "capped 250 W".into(),
        units::secs(capped_s),
        format!("{:.1} kJ", capped_j / 1e3),
    ]);
    t.print();
    println!(
        "\ncollectives moved {} across the fabric in the contended run",
        units::si(moved, "B")
    );

    // the §6.2 teaching points, asserted
    anyhow::ensure!(
        cont_s > solo_s * 1.02,
        "contention must stretch the barrier"
    );
    anyhow::ensure!(
        capped_s > solo_s * 1.02,
        "power caps must stretch the compute phases"
    );
    println!("app_workloads OK");
    Ok(())
}
