//! MPI compute/communication overlap — the §6.2 education use case.
//!
//! "The 'slow' network is also noteworthy because it saturates very
//! quickly. Therefore, even with a small number of nodes, it becomes
//! important to consider optimizing network communications when
//! designing prototypes. This provides a great opportunity to introduce
//! MPI compute/communication overlapping."
//!
//! The exercise: a 4-node iterative stencil-style job on az4-n4090
//! (2.5 GbE NICs). Each iteration computes a gemm512-sized step (cost
//! grounded by a real PJRT execution) and exchanges halo buffers with
//! both neighbours. Two implementations are compared on the flow-level
//! network simulation:
//!   * blocking    — compute, then exchange (MPI_Sendrecv style);
//!   * overlapped  — exchange of iteration i runs during compute of
//!                   i+1 (MPI_Isend/Irecv + wait), hiding whichever of
//!                   the two phases is shorter.
//!
//! Run: `cargo run --release --example mpi_overlap`

use dalek::config::ClusterConfig;
use dalek::net::{FlowNet, Topology};
use dalek::runtime::PjRtRuntime;
use dalek::util::{units, Table};

/// One ring-exchange round: every node sends its halo to the next node.
fn exchange_secs(topo: &Topology, nodes: &[dalek::net::HostId], bytes: u64) -> f64 {
    let mut net = FlowNet::new(topo);
    for (i, &src) in nodes.iter().enumerate() {
        let dst = nodes[(i + 1) % nodes.len()];
        net.start_flow(src, dst, bytes);
        let dst2 = nodes[(i + nodes.len() - 1) % nodes.len()];
        net.start_flow(src, dst2, bytes);
    }
    net.run_to_idle().as_secs_f64()
}

fn main() -> anyhow::Result<()> {
    println!("== §6.2 MPI compute/communication overlap on 2.5 GbE ==\n");
    let artifact_dir = "artifacts";
    anyhow::ensure!(
        std::path::Path::new(artifact_dir).join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    // ground the per-iteration compute cost with a real PJRT run
    let mut rt = PjRtRuntime::load(artifact_dir)?;
    let exec = rt.execute_best_of("gemm512", 11, 3)?;
    println!(
        "real PJRT run: gemm512 = {} / call ({})",
        units::secs(exec.wall_s),
        units::si(exec.flops_per_sec, "FLOP/s")
    );
    // per-iteration compute on an az4 node (CPU path, 25% of peak):
    // a stencil step of 20 gemm512-sized blocks per node
    const CALLS_PER_ITER: f64 = 20.0;
    let node = dalek::config::cluster::resolve_partition("az4-n4090")
        .expect("catalog")
        .node;
    let peak = node
        .cpu
        .peak_ops_accumulated(dalek::hw::cpu::Instr::FmaF32);
    let compute_s = CALLS_PER_ITER * exec.flops as f64 / (peak * 0.25);

    let topo = Topology::build(&ClusterConfig::dalek_default());
    let nodes = topo.partition_nodes(0); // az4-n4090, 2.5 GbE
    let iters = 100u32;

    let mut t = Table::new(&[
        "halo size", "comm/iter", "compute/iter", "blocking total", "overlap total", "speedup",
    ])
    .title(format!("{iters} iterations, 4-node ring, both-neighbour halo exchange"))
    .left(0);

    let mut crossover: Option<u64> = None;
    for halo_mb in [1u64, 2, 4, 8, 16, 64] {
        let bytes = halo_mb * 1_000_000;
        let comm_s = exchange_secs(&topo, &nodes, bytes);
        // blocking: phases serialize; overlapped: max of the two phases
        // (+ one non-hidden exchange at the end)
        let blocking = iters as f64 * (compute_s + comm_s);
        let overlapped = iters as f64 * compute_s.max(comm_s) + comm_s.min(compute_s);
        if comm_s > compute_s && crossover.is_none() {
            crossover = Some(halo_mb);
        }
        t.row(&[
            format!("{halo_mb} MB"),
            units::secs(comm_s),
            units::secs(compute_s),
            units::secs(blocking),
            units::secs(overlapped),
            format!("{:.2}x", blocking / overlapped),
        ]);
    }
    t.print();

    println!(
        "\nthe 2.5 GbE fabric saturates quickly: beyond ~{} MB halos the\n\
         exchange dominates compute and overlap approaches its 2x bound —\n\
         the teaching point of §6.2.",
        crossover.unwrap_or(64)
    );
    // overlap must help and never hurt
    anyhow::ensure!(crossover.is_some(), "expected a comm-bound crossover");
    anyhow::ensure!(
        (2..=16).contains(&crossover.unwrap()),
        "crossover should sit in the single-digit-MB halo range on 2.5 GbE"
    );
    println!("mpi_overlap OK");
    Ok(())
}
