//! CNN convolution energy benchmarking — the Galvez et al. use case
//! (paper §6.1 "Energy": DP2E-AI'25 work on the az5-a890m partition).
//!
//! The experiment: run the CNN forward payload (a real AOT-compiled
//! JAX + Pallas artifact executed over PJRT) on the az5-a890m model
//! under a sweep of RAPL power caps, with §4 probes sampling at 1000
//! SPS and a GPIO tag marking the measured region, and report
//! time-to-solution, average power, energy-to-solution and energy/image
//! per cap — the energy/performance trade-off curve.
//!
//! Run: `cargo run --release --example cnn_energy`

use dalek::config::cluster::resolve_partition;
use dalek::energy::{Ina228Probe, ProbeConfig};
use dalek::power::{Activity, PowerModel};
use dalek::runtime::PjRtRuntime;
use dalek::sim::SimTime;
use dalek::util::{units, Table, Xoshiro256};

fn main() -> anyhow::Result<()> {
    println!("== CNN convolution energy sweep on az5-a890m (Galvez use case) ==\n");
    let artifact_dir = "artifacts";
    anyhow::ensure!(
        std::path::Path::new(artifact_dir).join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    // 1. ground the payload cost with a real PJRT execution
    let mut rt = PjRtRuntime::load(artifact_dir)?;
    let exec = rt.execute_best_of("cnn_small", 7, 3)?;
    println!(
        "real PJRT run: cnn_small = {} / call ({}), checksum {:.4}",
        units::secs(exec.wall_s),
        units::si(exec.flops_per_sec, "FLOP/s"),
        exec.output_sum
    );
    let images_per_call = 8u64; // batch size of cnn_small
    let calls = 20_000u64;

    // 2. sweep RAPL caps on the az5-a890m node model
    let node = resolve_partition("az5-a890m").expect("catalog").node;
    let act = Activity::cpu_only(0.95);
    let roofline = node
        .cpu
        .peak_ops_accumulated(dalek::hw::cpu::Instr::FmaF32);
    const ETA: f64 = 0.25; // sustained fraction of peak for conv-as-GEMM

    let mut t = Table::new(&[
        "RAPL cap", "avg power", "time-to-solution", "energy", "J/image", "probe J",
    ])
    .title("energy/performance trade-off, 20k CNN forward calls (batch 8)")
    .left(0);

    let mut best_j_per_image = f64::INFINITY;
    let mut best_cap = String::new();
    for cap_w in [None, Some(45.0), Some(35.0), Some(25.0), Some(15.0)] {
        let mut power = PowerModel::for_node(&node);
        power.cpu_rapl.set_cap(cap_w).expect("within bounds");
        let perf = power.cpu_perf_factor(act);
        let watts = power.watts(act);
        let total_flops = exec.flops as f64 * calls as f64;
        let secs = total_flops / (roofline * ETA * perf);
        let energy_j = watts * secs;
        let j_per_image = energy_j / (calls * images_per_call) as f64;

        // 3. measure the same window through a §4 probe with a GPIO tag
        let mut probe = Ina228Probe::new(0, ProbeConfig::default(), Xoshiro256::new(42));
        let window = SimTime::from_secs_f64(secs.min(30.0)); // sample ≤30 s
        let samples = probe.sample_until(&|_t: SimTime| watts, window, 0b1);
        let probe_j: f64 = samples.iter().map(|s| s.power_w * 1e-3).sum::<f64>()
            * (secs / window.as_secs_f64());

        if j_per_image < best_j_per_image {
            best_j_per_image = j_per_image;
            best_cap = cap_w.map(|c| format!("{c:.0} W")).unwrap_or("none".into());
        }
        t.row(&[
            cap_w.map(|c| format!("{c:.0} W")).unwrap_or("none".into()),
            units::watts(watts),
            units::secs(secs),
            units::joules(energy_j),
            format!("{:.2} mJ", j_per_image * 1e3),
            units::joules(probe_j),
        ]);
    }
    t.print();
    println!(
        "\nmost energy-efficient cap: {best_cap} ({:.2} mJ/image) — capping trades \
         (cap/demand)^(1/3) performance for linear power, so energy/op falls",
        best_j_per_image * 1e3
    );
    println!("cnn_energy OK");
    Ok(())
}
