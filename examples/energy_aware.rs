//! The §3.4 energy-aware powering strategy, measured end to end.
//!
//! Replays the same bursty daily workload twice — suspend policy ON
//! (the paper's deployment) and OFF (conventional always-on cluster) —
//! and reports the energy saved, the queue-wait cost (the ≤2-minute
//! boot delay users pay), and the idle-cluster power floor.
//!
//! Run: `cargo run --release --example energy_aware`

use dalek::config::ClusterConfig;
use dalek::coordinator::{trace, Cluster};
use dalek::sim::SimTime;
use dalek::slurm::JobSpec;
use dalek::util::{units, Table};

fn bursty_trace(seed: u64) -> Vec<trace::TraceEvent> {
    // a working day: two bursts (morning, afternoon) + overnight silence
    let mut gen = trace::TraceGen::dalek_mix(seed);
    gen.payloads.clear();
    gen.jobs_per_hour = 30.0;
    let mut t = gen.generate(40);
    for (i, ev) in t.iter_mut().enumerate() {
        let base = if i < 20 {
            SimTime::from_hours(9) // morning burst
        } else {
            SimTime::from_hours(14) // afternoon burst
        };
        ev.at = base + SimTime::from_secs((i as u64 % 20) * 90);
    }
    t
}

fn run(enabled: bool) -> (trace::ReplayReport, f64, u32, u32) {
    let mut cfg = ClusterConfig::dalek_default();
    cfg.power.enabled = enabled;
    let mut cluster = Cluster::new(cfg, None).expect("cluster");
    if !enabled {
        // conventional cluster: everything is booted at 07:00 and stays up
        for p in ["az4-n4090", "az4-a7900", "iml-ia770", "az5-a890m"] {
            cluster
                .submit(JobSpec::cpu("ops", p, 4, 1), SimTime::from_hours(7))
                .expect("warmup job");
        }
    }
    let tr = bursty_trace(0xE17);
    let report = trace::replay(&mut cluster, &tr, false);
    // extend to the full 24 h day so overnight idling is accounted
    cluster.run_until(SimTime::from_hours(24), false);
    let day_energy = cluster.report().true_energy_j;
    let infos = cluster.slurm().node_infos();
    let boots = infos.iter().map(|n| n.boots).sum();
    let suspends = infos.iter().map(|n| n.suspends).sum();
    (report, day_energy, boots, suspends)
}

fn main() -> anyhow::Result<()> {
    println!("== §3.4 energy-aware node powering: a bursty day, ON vs OFF ==\n");
    let (r_on, e_on, boots_on, susp_on) = run(true);
    let (r_off, e_off, boots_off, _susp_off) = run(false);

    let mut t = Table::new(&["metric", "suspend ON (paper)", "always-on"])
        .title("daily comparison (40 jobs in two bursts, 24 h accounting)")
        .left(0);
    t.row(&[
        "energy / day (computes)".into(),
        units::joules(e_on),
        units::joules(e_off),
    ]);
    t.row(&[
        "mean draw".into(),
        units::watts(e_on / 86_400.0),
        units::watts(e_off / 86_400.0),
    ]);
    t.row(&[
        "jobs completed".into(),
        r_on.completed.to_string(),
        r_off.completed.to_string(),
    ]);
    let wait = |r: &trace::ReplayReport| {
        r.wait
            .as_ref()
            .map(|w| format!("{} / {}", units::secs(w.p50), units::secs(w.max)))
            .unwrap_or_default()
    };
    t.row(&["wait p50 / max".into(), wait(&r_on), wait(&r_off)]);
    t.row(&[
        "node boots / suspends".into(),
        format!("{boots_on} / {susp_on}"),
        format!("{boots_off} / always up"),
    ]);
    t.print();

    let saved = 1.0 - e_on / e_off;
    println!(
        "\nsuspend policy saves {:.0}% of daily compute-node energy;",
        saved * 100.0
    );
    if let Some(w) = &r_on.wait {
        println!(
            "the price is boot-delayed starts: max wait {} (paper budget: ≤2 min + queue).",
            units::secs(w.max)
        );
        anyhow::ensure!(w.p50 <= 150.0, "median wait must sit within the boot budget");
    }
    anyhow::ensure!(saved > 0.5, "sparse day must save >50% energy");
    // always-on ran 4 extra warmup jobs (one per partition at 07:00)
    anyhow::ensure!(
        r_on.completed + 4 == r_off.completed,
        "same trace work must complete: {} vs {}",
        r_on.completed,
        r_off.completed
    );
    println!("energy_aware OK");
    Ok(())
}
