//! DQL — querying a live cluster and standing on its event stream.
//!
//! Exercises the query layer end to end: run a seeded morning of jobs,
//! then (1) point-query the virtual tree with path expressions and
//! windowed aggregates through `Request::Query`, and (2) register a
//! standing query on the `query_events` channel and watch delta events
//! arrive as the cluster's power draw moves. Everything is owner-scoped:
//! the same expression answers differently for `alice` than for `root`.
//!
//! Run: `cargo run --release --example query`

use dalek::api::{Channel, ClusterApi, Request, Response};
use dalek::config::ClusterConfig;
use dalek::coordinator::trace::TraceGen;
use dalek::sim::SimTime;

fn main() -> anyhow::Result<()> {
    println!("== DALEK query layer: DQL over cluster state and rolling telemetry ==\n");

    let mut cluster = ClusterApi::new(ClusterConfig::dalek_default(), None)?;
    let root = cluster.login("root")?;
    cluster.add_user("alice");
    let alice = cluster.login("alice")?;

    // a seeded morning of work so the tree has something to say
    let mut gen = TraceGen::dalek_mix(0xD01);
    gen.payloads.clear();
    for ev in gen.generate(10) {
        cluster.submit(ev.spec.clone(), ev.at)?;
    }
    cluster.run_until(SimTime::from_hours(1), false);

    // 1) point queries: paths, predicates, windowed aggregates
    println!("-- point queries (root) --");
    for src in [
        "cluster.watts",
        "sum(nodes.*.power.energy_j)",
        "count(nodes[capped=true])",
        "mean(nodes[partition=\"az5-a890m\"].power.watts, window=60s)",
        "partitions.*.queue.depth",
    ] {
        let (expr, result) = cluster.query(root, src)?;
        println!("  {expr}\n    = {}", dalek::query::output_json(&result));
    }

    // owner scoping: alice sees her jobs, root sees everyone's
    let (_, mine) = cluster.query(alice, "count(jobs.*)")?;
    let (_, all) = cluster.query(root, "count(jobs.*)")?;
    println!("\n-- scoping --\n  alice's count(jobs.*) = {}", dalek::query::output_json(&mine));
    println!("  root's   count(jobs.*) = {}", dalek::query::output_json(&all));

    // 2) a standing query: re-evaluated on job/power edges and on a
    // 0.2 Hz grid, delivering only *changed* results as events
    let resp = cluster.handle(
        Some(root),
        &Request::Subscribe {
            channel: Channel::QueryEvents,
            rate_hz: Some(0.2),
            expr: Some("sum(nodes.*.power.watts)".into()),
        },
    )?;
    assert!(matches!(resp, Response::Subscribed { .. }));
    let mut gen = TraceGen::dalek_mix(0xD02);
    gen.payloads.clear();
    for ev in gen.generate(6) {
        let mut spec = ev.spec.clone();
        spec.user = "alice".into();
        cluster.submit(spec, cluster.now() + ev.at)?;
    }
    cluster.run_until(cluster.now() + SimTime::from_mins(30), false);

    println!("\n-- standing query: sum(nodes.*.power.watts) deltas --");
    let events = cluster.take_events(root, usize::MAX);
    let shown = events.len().min(8);
    for ev in events.iter().take(shown) {
        println!("  {}", ev.to_json());
    }
    println!("  ({} delta events total, {shown} shown)", events.len());
    Ok(())
}
